"""Command-line tools (paper Listing 1): dj-process / dj-analyze analogues,
all thin shells over the shared Pipeline API (repro.api).

  python -m repro.interface.cli process --config recipe.{json,yaml}
  python -m repro.interface.cli sql "SELECT ..." [--dataset_path x.jsonl]
  python -m repro.interface.cli explain --config recipe.{json,yaml}
  python -m repro.interface.cli explain --sql "SELECT ..." [--dataset_path ..]
  python -m repro.interface.cli analyze --dataset_path x.jsonl [--auto]
  python -m repro.interface.cli list-ops
  python -m repro.interface.cli runner --cluster_dir DIR [--capacity N]
  python -m repro.interface.cli submit --config recipe.{json,yaml} \
      --cluster_dir DIR [--tenant T] [--job_id ID] [--wait]
  python -m repro.interface.cli cluster-status --cluster_dir DIR \
      [--slo] [--tenants]
  python -m repro.interface.cli trace JOB_ID --cluster_dir DIR [--out F]
"""
from __future__ import annotations

import argparse
import sys


def _print_report(report) -> None:
    print(f"recipe={report.recipe} in={report.n_in} out={report.n_out} "
          f"seconds={report.seconds:.2f} plan={report.plan}")
    for row in report.per_op:
        print(f"  {row['op']:40s} {row['seconds']:.3f}s "
              f"{row['in']}->{row['out']} ({row['speed']:.0f} samples/s)")
    if report.insight:
        print(report.insight)


def main(argv=None):
    ap = argparse.ArgumentParser(prog="dj")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_proc = sub.add_parser("process", help="run a recipe")
    p_proc.add_argument("--config", required=True)
    p_proc.add_argument("--np", type=int, default=0)

    p_sql = sub.add_parser("sql", help="compile and run a SQL query over the "
                                       "shared logical plan")
    p_sql.add_argument("query")
    p_sql.add_argument("--dataset_path", default=None,
                       help="input jsonl (or quote a path in FROM)")
    p_sql.add_argument("--export_path", default=None)
    p_sql.add_argument("--np", type=int, default=0)

    p_ex = sub.add_parser("explain", help="show the optimized plan/segments "
                                          "without processing the dataset "
                                          "(probes a small head sample to "
                                          "estimate op speeds)")
    p_ex.add_argument("--config", default=None)
    p_ex.add_argument("--sql", default=None, dest="sql_query",
                      help="explain a SQL query instead of a recipe")
    p_ex.add_argument("--dataset_path", default=None,
                      help="input jsonl for --sql (or quote a path in FROM)")

    p_an = sub.add_parser("analyze", help="compute default stats + report")
    p_an.add_argument("--dataset_path", required=True)
    p_an.add_argument("--auto", action="store_true",
                      help="auto-discover every applicable stat op")
    p_an.add_argument("--limit", type=int, default=0,
                      help="analyze only the first N samples")

    sub.add_parser("list-ops", help="print the OP registry")

    p_run = sub.add_parser("runner", help="run a cluster job runner: lease "
                                          "jobs from a shared cluster_dir, "
                                          "execute them with heartbeats and "
                                          "segment-checkpoint failover")
    p_run.add_argument("--cluster_dir", required=True)
    p_run.add_argument("--runner_id", default=None)
    p_run.add_argument("--capacity", type=int, default=1,
                       help="concurrent jobs this runner holds leases for")
    p_run.add_argument("--lease_ttl", type=float, default=None,
                       help="seconds a lease survives without a heartbeat")
    p_run.add_argument("--poll", type=float, default=0.2)
    p_run.add_argument("--defer", type=float, default=None, dest="defer_s",
                       help="placement deference window in seconds (how long "
                            "a worse-placed runner leaves a job for a better "
                            "one before claiming it anyway)")
    p_run.add_argument("--once", action="store_true",
                       help="claim and run at most one job, then exit")

    p_sub = sub.add_parser("submit", help="durably enqueue a recipe into a "
                                          "cluster queue (executed by "
                                          "whichever runners lease it) under "
                                          "a tenant identity")
    p_sub.add_argument("--config", required=True)
    p_sub.add_argument("--cluster_dir", required=True)
    p_sub.add_argument("--tenant", default=None,
                       help="owning tenant (quota admission, fair-share "
                            "claiming, per-tenant SLOs); defaults to the "
                            "recipe's tenant field or the default tenant")
    p_sub.add_argument("--job_id", default=None)
    p_sub.add_argument("--wait", action="store_true",
                       help="block until the job reaches a terminal state")

    p_cs = sub.add_parser("cluster-status", help="print the cluster queue "
                                                 "overview (runners, leases, "
                                                 "queue depth)")
    p_cs.add_argument("--cluster_dir", required=True)
    p_cs.add_argument("--slo", action="store_true",
                      help="also print SLO rollups from the event log "
                           "(queue-wait percentiles, per-runner AND "
                           "per-tenant throughput, failover/preemption "
                           "counts)")
    p_cs.add_argument("--tenants", action="store_true",
                      help="also print the per-tenant rollup (weight, quota, "
                           "live jobs, claims granted)")

    p_tr = sub.add_parser("trace", help="merge a job's span spills into one "
                                        "Chrome-trace JSON (open in "
                                        "chrome://tracing or Perfetto)")
    p_tr.add_argument("job_id")
    p_tr.add_argument("--cluster_dir", required=True)
    p_tr.add_argument("--out", default=None,
                      help="output path (default TRACE_<job_id>.json)")

    args = ap.parse_args(argv)

    if args.cmd == "list-ops":
        from repro.core.registry import list_ops, op_info

        for n in list_ops():
            info = op_info(n)
            print(f"{n:40s} {info['type']:12s} {info['doc'][:60]}")
        return 0

    if args.cmd == "process":
        from repro.api import Pipeline
        from repro.core.recipes import Recipe

        pipe = Pipeline.from_recipe(Recipe.load(args.config))
        if args.np:
            pipe = pipe.options(np=args.np)
        _, report = pipe.execute()
        _print_report(report)
        return 0

    if args.cmd == "sql":
        from repro.api.sql import SQLError, parse_sql, sql

        try:
            q = parse_sql(args.query)
            base = args.dataset_path or (q.source if q.source_is_path
                                         else None)
            out_path = args.export_path or (base + ".out.jsonl" if base
                                            else None)
            pipe = sql(args.query, dataset_path=args.dataset_path,
                       export_path=out_path)
        except SQLError as e:
            print(f"sql error [{e.kind}]: {e}", file=sys.stderr)
            return 1
        if args.np:
            pipe = pipe.options(np=args.np)
        _, report = pipe.execute()
        _print_report(report)
        if out_path:
            print(f"exported -> {out_path}")
        return 0

    if args.cmd == "explain":
        from repro.api import Pipeline
        from repro.core.recipes import Recipe

        if bool(args.sql_query) == bool(args.config):
            print("explain needs exactly one of --config or --sql",
                  file=sys.stderr)
            return 1
        if args.sql_query:
            from repro.api.sql import SQLError, sql

            try:
                pipe = sql(args.sql_query, dataset_path=args.dataset_path)
            except SQLError as e:
                print(f"sql error [{e.kind}]: {e}", file=sys.stderr)
                return 1
        else:
            pipe = Pipeline.from_recipe(Recipe.load(args.config))
        info = pipe.explain()
        print(f"recipe={info['recipe']} engine={info['engine']} np={info['np']} "
              f"streaming={info['streaming']}")
        print(f"requested: {' -> '.join(info['requested'])}")
        print(f"optimized: {' -> '.join(info['plan'])}")
        for nd in info.get("nodes", []):
            if nd["kind"] in ("source", "sink"):
                extra = " ".join(f"{k}={v}" for k, v in nd.items()
                                 if k not in ("kind", "name"))
                print(f"  {nd['kind']:8s} {nd['name']:40s} {extra}")
                continue
            flags = "".join(f" [{f}]" for f in
                            ("pushdown", "columnar", "barrier", "stateful")
                            if nd.get(f))
            print(f"  {nd['kind']:8s} {nd['name']:40s} "
                  f"reads={','.join(nd['reads']) or '-'} "
                  f"writes={','.join(nd['writes']) or '-'}{flags}")
        for rw in info.get("rewrites", []):
            if not rw["changed"]:
                print(f"  rule {rw['rule']:22s} [no-op]")
            elif rw["before"] != rw["after"]:
                print(f"  rule {rw['rule']:22s} [changed] "
                      f"{' -> '.join(rw['before'])}")
                print(f"       {'':22s}        => {' -> '.join(rw['after'])}")
            else:
                # annotation-only rule: the chain is unchanged, the diff is
                # in the marks it set
                detail = " ".join(f"{k}={v}" for k, v in
                                  sorted(rw.get("detail", {}).items()))
                print(f"  rule {rw['rule']:22s} [marked] {detail}")
        for i, seg in enumerate(info["segments"]):
            kind = "barrier" if seg["barrier"] else (
                "stateful" if seg.get("stateful") else "stream ")
            print(f"  segment {i} [{kind}]: {' -> '.join(seg['ops'])}")
        return 0

    if args.cmd == "runner":
        from repro.api.cluster import ClusterQueue, ClusterRunner, PlacementPolicy

        queue = ClusterQueue(args.cluster_dir)
        if args.lease_ttl:
            queue.lease_ttl = args.lease_ttl
        policy = None if args.defer_s is None \
            else PlacementPolicy(defer_seconds=args.defer_s)
        runner = ClusterRunner(queue, runner_id=args.runner_id,
                               capacity=args.capacity,
                               lease_ttl=args.lease_ttl, poll=args.poll,
                               policy=policy)
        print(f"runner {runner.runner_id} leasing from {queue.dir} "
              f"(capacity={runner.capacity}, ttl={runner.lease_ttl}s)",
              flush=True)
        if args.once:
            ran = runner.run_once()
            print(f"runner {runner.runner_id}: "
                  f"{'ran one job' if ran else 'queue empty'}")
            return 0
        try:
            runner.run_forever()
        except KeyboardInterrupt:
            runner.drain()
        return 0

    if args.cmd == "submit":
        import time as _time

        from repro.api.cluster import (AdmissionDenied, ClusterQueue,
                                       TERMINAL)
        from repro.core.recipes import Recipe

        queue = ClusterQueue(args.cluster_dir)
        recipe = Recipe.load(args.config)
        try:
            jid = queue.submit(recipe.to_dict(), job_id=args.job_id,
                               tenant=args.tenant)
        except AdmissionDenied as e:
            print(f"admission denied [{e.scope}]: {e}", file=sys.stderr)
            return 1
        spec = queue.read_spec(jid)
        print(f"submitted {jid} tenant={spec.get('tenant')} "
              f"-> {queue.dir}", flush=True)
        if not args.wait:
            return 0
        while True:
            state = queue.state_of(jid)
            if state in TERMINAL:
                break
            _time.sleep(0.2)
        st = queue.status(jid, verbose=False)
        print(f"job {jid} {st['state']}"
              + (f" error={st['error']}" if st.get("error") else ""))
        return 0 if st["state"] == "succeeded" else 1

    if args.cmd == "cluster-status":
        from repro.api.cluster import ClusterQueue

        cq = ClusterQueue(args.cluster_dir)
        ov = cq.overview()
        jobs = " ".join(f"{k}={v}" for k, v in sorted(ov["jobs"].items()))
        print(f"cluster {ov['cluster_dir']}")
        print(f"queue_depth={ov['queue_depth']} {jobs}")
        for c in ov["runners"]:
            live = "live" if c.get("alive") else "dead"
            print(f"  runner {c['runner_id']:28s} [{live}] "
                  f"active={c.get('active', 0)}/{c.get('capacity', 1)} "
                  f"throughput={c.get('throughput', 0.0):.1f}/s "
                  f"quarantines={c.get('quarantines', 0)} "
                  f"score={c.get('score', 0.0):.2f}")
        for l in ov["leases"]:
            mark = "EXPIRED" if l["expired"] else "live"
            print(f"  lease {l['job_id']} -> {l['runner_id']} "
                  f"attempt={l['attempt']} [{mark}]")
        for parent, rows in sorted(ov.get("sharded", {}).items()):
            print(f"  sharded {parent}: {len(rows)} tasks")
            for r in rows:
                extra = ""
                if r.get("resumed_at"):
                    extra += f" resumed_at={r['resumed_at']}"
                if r.get("n_out") is not None:
                    extra += f" n_out={r['n_out']}"
                if r.get("lease_expired"):
                    extra += " [EXPIRED]"
                print(f"    {r['kind']:8s} {r['task_id']:24s} "
                      f"{r['state']:10s} attempt={r.get('attempt', 0)} "
                      f"runner={r.get('runner_id') or '-'}{extra}")
        if args.slo:
            from repro.api.slo import cluster_slo

            slo = cluster_slo(args.cluster_dir)
            qw = slo["queue_wait"]
            print(f"slo queue_wait n={qw['n']} p50={qw['p50']:.3f}s "
                  f"p95={qw['p95']:.3f}s max={qw['max']:.3f}s")
            print(f"slo failovers={slo['failovers']} "
                  f"preempted={slo['preempted']} "
                  f"redispatches={slo['redispatches']} "
                  f"jobs_finished={slo['jobs_finished']} "
                  f"jobs_failed={slo['jobs_failed']}")
            for rid, t in slo["throughput"].items():
                print(f"  throughput {rid:28s} jobs={t['jobs']} "
                      f"rows={t['rows']} "
                      f"rows_per_second={t['rows_per_second']:.1f}")
            for name, t in slo.get("tenants", {}).items():
                tqw = t["queue_wait"]
                print(f"  tenant {name:24s} waits n={tqw['n']} "
                      f"p50={tqw['p50']:.3f}s p95={tqw['p95']:.3f}s "
                      f"finished={t['jobs_finished']} "
                      f"rows_per_second={t['rows_per_second']:.1f}")
        if args.tenants:
            for row in cq.tenant_overview():
                quota = row["max_live_jobs"]
                jobs = " ".join(f"{k}={v}"
                                for k, v in sorted(row["jobs"].items()))
                print(f"  tenant {row['tenant']:24s} "
                      f"weight={row['weight']:g} "
                      f"quota={'-' if quota is None else quota} "
                      f"live={row['live_jobs']} "
                      f"claims={row['claims_granted']:g} "
                      f"keys={row['api_keys']}"
                      + (f" [{jobs}]" if jobs else ""))
        return 0

    if args.cmd == "trace":
        import json

        from repro.api.cluster import ClusterQueue
        from repro.core import obs

        queue = ClusterQueue(args.cluster_dir)
        try:
            spec = queue.read_spec(args.job_id)
        except KeyError:
            print(f"no job {args.job_id!r} in {queue.dir}", file=sys.stderr)
            return 1
        tr = spec.get("trace") or {}
        if not tr.get("trace_id"):
            print(f"job {args.job_id} has no trace (submitted with "
                  f"tracing disabled?)", file=sys.stderr)
            return 1
        spans = obs.merge_trace(queue.obs_dir(), tr["trace_id"])
        tree = obs.span_tree(spans)
        out_path = args.out or f"TRACE_{args.job_id}.json"
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(obs.chrome_trace(spans), f)
        print(f"trace {tr['trace_id']}: {len(spans)} spans "
              f"({len(tree['roots'])} roots, {len(tree['orphans'])} orphans) "
              f"-> {out_path}")
        return 0

    if args.cmd == "analyze":
        from repro.api import analyze

        res = analyze(args.dataset_path, auto=args.auto,
                      limit=args.limit or None)
        print(f"n={res['n']} ops={','.join(res['ops'])}")
        for k, st in sorted(res["numeric"].items()):
            print(f"  {k:24s} mean={st.mean:.3f} p50={st.p50:.3f} p95={st.p95:.3f}")
        for k, counts in sorted(res["tags"].items()):
            top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
            print(f"  {k:24s} " + " ".join(f"{t}:{c}" for t, c in top))
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
