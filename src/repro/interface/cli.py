"""Command-line tools (paper Listing 1): dj-process / dj-analyze analogues,
all thin shells over the shared Pipeline API (repro.api).

  python -m repro.interface.cli process --config recipe.{json,yaml}
  python -m repro.interface.cli explain --config recipe.{json,yaml}
  python -m repro.interface.cli analyze --dataset_path x.jsonl [--auto]
  python -m repro.interface.cli list-ops
"""
from __future__ import annotations

import argparse
import sys


def _print_report(report) -> None:
    print(f"recipe={report.recipe} in={report.n_in} out={report.n_out} "
          f"seconds={report.seconds:.2f} plan={report.plan}")
    for row in report.per_op:
        print(f"  {row['op']:40s} {row['seconds']:.3f}s "
              f"{row['in']}->{row['out']} ({row['speed']:.0f} samples/s)")
    if report.insight:
        print(report.insight)


def main(argv=None):
    ap = argparse.ArgumentParser(prog="dj")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_proc = sub.add_parser("process", help="run a recipe")
    p_proc.add_argument("--config", required=True)
    p_proc.add_argument("--np", type=int, default=0)

    p_ex = sub.add_parser("explain", help="show the optimized plan/segments "
                                          "without processing the dataset "
                                          "(probes a small head sample to "
                                          "estimate op speeds)")
    p_ex.add_argument("--config", required=True)

    p_an = sub.add_parser("analyze", help="compute default stats + report")
    p_an.add_argument("--dataset_path", required=True)
    p_an.add_argument("--auto", action="store_true",
                      help="auto-discover every applicable stat op")
    p_an.add_argument("--limit", type=int, default=0,
                      help="analyze only the first N samples")

    sub.add_parser("list-ops", help="print the OP registry")

    args = ap.parse_args(argv)

    if args.cmd == "list-ops":
        from repro.core.registry import list_ops, op_info

        for n in list_ops():
            info = op_info(n)
            print(f"{n:40s} {info['type']:12s} {info['doc'][:60]}")
        return 0

    if args.cmd == "process":
        from repro.api import Pipeline
        from repro.core.recipes import Recipe

        pipe = Pipeline.from_recipe(Recipe.load(args.config))
        if args.np:
            pipe = pipe.options(np=args.np)
        _, report = pipe.execute()
        _print_report(report)
        return 0

    if args.cmd == "explain":
        from repro.api import Pipeline
        from repro.core.recipes import Recipe

        info = Pipeline.from_recipe(Recipe.load(args.config)).explain()
        print(f"recipe={info['recipe']} engine={info['engine']} np={info['np']} "
              f"streaming={info['streaming']}")
        print(f"requested: {' -> '.join(info['requested'])}")
        print(f"optimized: {' -> '.join(info['plan'])}")
        for i, seg in enumerate(info["segments"]):
            kind = "barrier" if seg["barrier"] else (
                "stateful" if seg.get("stateful") else "stream ")
            print(f"  segment {i} [{kind}]: {' -> '.join(seg['ops'])}")
        return 0

    if args.cmd == "analyze":
        from repro.api import analyze

        res = analyze(args.dataset_path, auto=args.auto,
                      limit=args.limit or None)
        print(f"n={res['n']} ops={','.join(res['ops'])}")
        for k, st in sorted(res["numeric"].items()):
            print(f"  {k:24s} mean={st.mean:.3f} p50={st.p50:.3f} p95={st.p95:.3f}")
        for k, counts in sorted(res["tags"].items()):
            top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
            print(f"  {k:24s} " + " ".join(f"{t}:{c}" for t, c in top))
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
