"""RESTful API layer (paper §4, Appendix C.2) — dependency-free
``http.server`` implementation with automatic OP discovery.

  GET  /ops              — discover + register all OP classes
  GET  /ops/<name>       — one OP's metadata
  POST /run/<op_name>?dataset_path=...   body: JSON op params
                         — executes op.run() on the dataset, returns the
                           processed dataset path
  POST /process?dataset_path=...          body: JSON recipe
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

from repro.core.storage import json_dumps, json_loads


class _Handler(BaseHTTPRequestHandler):
    def _send(self, code: int, payload):
        body = json_dumps(payload)
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):  # quiet
        pass

    def do_GET(self):
        from repro.core.registry import list_ops, op_info

        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        if parts == ["ops"]:
            return self._send(200, {"ops": [op_info(n) for n in list_ops()]})
        if len(parts) == 2 and parts[0] == "ops":
            try:
                return self._send(200, op_info(parts[1]))
            except KeyError:
                return self._send(404, {"error": f"unknown op {parts[1]}"})
        return self._send(404, {"error": "not found"})

    def do_POST(self):
        from repro.core.dataset import DJDataset
        from repro.core.executor import Executor
        from repro.core.recipes import Recipe
        from repro.core.registry import create_op

        url = urlparse(self.path)
        qs = parse_qs(url.query)
        n = int(self.headers.get("Content-Length", 0))
        params = json_loads(self.rfile.read(n) or b"{}")
        parts = [p for p in url.path.split("/") if p]
        try:
            dataset_path = qs.get("dataset_path", [None])[0]
            if not dataset_path:
                return self._send(400, {"error": "dataset_path query param required"})
            out_path = qs.get("export_path", [dataset_path + ".out.jsonl"])[0]
            if len(parts) == 2 and parts[0] == "run":
                op = create_op({"name": parts[1], **params})
                ds = DJDataset.load(dataset_path)
                ds = op.run(ds)
                ds.export(out_path)
                return self._send(200, {
                    "status": "ok", "export_path": out_path,
                    "n_out": len(ds), "errors": len(op.errors),
                })
            if parts == ["process"]:
                recipe = Recipe.from_dict({**params, "dataset_path": dataset_path,
                                           "export_path": out_path})
                _, report = Executor(recipe).run()
                return self._send(200, {
                    "status": "ok", "export_path": out_path,
                    "n_in": report.n_in, "n_out": report.n_out,
                    "plan": report.plan, "seconds": report.seconds,
                })
        except Exception as e:  # noqa: BLE001
            return self._send(500, {"error": f"{type(e).__name__}: {e}"})
        return self._send(404, {"error": "not found"})


def serve(host: str = "127.0.0.1", port: int = 8123) -> ThreadingHTTPServer:
    srv = ThreadingHTTPServer((host, port), _Handler)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    return srv
