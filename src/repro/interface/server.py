"""RESTful API layer (paper §4, Appendix C.2) — dependency-free
``http.server`` implementation with automatic OP discovery and an async
job subsystem, all routed through the shared Pipeline API.

  GET    /ops              — discover + register all OP classes
  GET    /ops/<name>       — one OP's metadata + typed signature
  POST   /run/<op_name>?dataset_path=...   body: JSON op params
                           — synchronous single-op run
  POST   /process?dataset_path=...         body: JSON recipe (synchronous)
  POST   /sql              body: {"query": "SELECT ...", "dataset_path"?,
                           "export_path"?} — compile the SQL dialect onto
                           the shared logical plan and run synchronously;
                           unknown columns 404 with did-you-mean
                           suggestions (same contract as /jobs unknown ops)
  POST   /jobs             body: JSON recipe — submit an async job,
                           returns {"job_id", ...} immediately
  GET    /jobs             — job summaries
  GET    /jobs/<id>        — state + live per-op progress + final report
  DELETE /jobs/<id>        — cancel (stops at the next block boundary)
  GET    /cluster          — cluster overview: runner cards + placement
                           scores, live/expired leases, queue depth
                           ({"enabled": false} outside cluster mode)
  GET    /cluster/slo      — p50/p95 queue-wait, per-runner AND per-tenant
                           throughput, failover/preemption counts from
                           log.jsonl; ?tenant=<id> narrows to one tenant's
                           breakdown ({"enabled": false} outside cluster
                           mode)
  GET    /tenants          — per-tenant weight/quota/live-jobs/service
                           rollup ({"enabled": false} outside cluster mode)
  GET    /metrics          — live in-process metrics registry snapshot,
                           plus the merged cross-process spills in
                           cluster mode

POST /jobs resolves the submitting tenant from the ``X-DJ-API-Key``
header via the cluster's tenants.json key map (unknown key -> 403), else
the body's ``tenant`` field, else the default tenant.

With ``serve(cluster_dir=...)`` the job subsystem runs on the distributed
cluster queue (repro.api.cluster): submissions are durably enqueued in the
shared store and executed by whichever runners lease them — the server's own
in-process runner and/or external ``dj runner`` processes. The /jobs
contract is identical in both modes.

Errors are structured: {"error": {"type", "message"}} with 400 for
malformed bodies/params, 404 for unknown ops/jobs/routes, 409 for invalid
transitions, 503 when the bounded job store is full.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.core.storage import json_dumps, json_loads


class DJServer(ThreadingHTTPServer):
    """HTTP server owning the shared JobManager."""

    def __init__(self, addr, handler, max_workers: int = 2, max_jobs: int = 64,
                 job_dir: str = None, cluster_dir: str = None):
        super().__init__(addr, handler)
        from repro.api.jobs import JobManager

        # job_dir makes the store durable: a restarted server reports prior
        # jobs from the JSONL snapshot (interrupted ones surface as failed);
        # cluster_dir replaces the in-memory store with the distributed
        # queue (durable, multi-runner, lease failover)
        self.jobs = JobManager(max_workers=max_workers, max_jobs=max_jobs,
                               job_dir=job_dir, cluster_dir=cluster_dir)

    def server_close(self):
        self.jobs.shutdown()
        super().server_close()


class _Handler(BaseHTTPRequestHandler):
    def _send(self, code: int, payload):
        body = json_dumps(payload)
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _err(self, code: int, etype: str, msg: str):
        return self._send(code, {"error": {"type": etype, "message": msg}})

    def log_message(self, *a):  # quiet
        pass

    def _read_body(self):
        """Parsed JSON body; raises ValueError on malformed JSON."""
        n = int(self.headers.get("Content-Length", 0))
        raw = self.rfile.read(n) if n else b""
        if not raw:
            return {}
        body = json_loads(raw)
        if not isinstance(body, dict):
            raise ValueError("JSON body must be an object")
        return body

    # ------------------------------------------------------------------
    def do_GET(self):
        from repro.core.registry import list_ops, op_info

        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        if parts == ["ops"]:
            return self._send(200, {"ops": [op_info(n) for n in list_ops()]})
        if len(parts) == 2 and parts[0] == "ops":
            try:
                return self._send(200, op_info(parts[1]))
            except KeyError:
                return self._err(404, "unknown_op", f"unknown op {parts[1]!r}")
        if parts == ["jobs"]:
            return self._send(200, {"jobs": self.server.jobs.list()})
        if len(parts) == 2 and parts[0] == "jobs":
            try:
                return self._send(200, self.server.jobs.get(parts[1]).status())
            except KeyError:
                return self._err(404, "unknown_job", f"no job {parts[1]!r}")
        if parts == ["cluster"]:
            return self._send(200, self.server.jobs.cluster_status())
        if parts == ["cluster", "slo"]:
            qs = parse_qs(url.query)
            tenant = qs.get("tenant", [None])[0]
            return self._send(200, self.server.jobs.cluster_slo(tenant=tenant))
        if parts == ["tenants"]:
            return self._send(200, self.server.jobs.tenants())
        if parts == ["metrics"]:
            return self._send(200, self.server.jobs.metrics_snapshot())
        return self._err(404, "not_found", "not found")

    # ------------------------------------------------------------------
    def do_DELETE(self):
        from repro.api.jobs import JobState

        parts = [p for p in urlparse(self.path).path.split("/") if p]
        if len(parts) == 2 and parts[0] == "jobs":
            jobs = self.server.jobs
            try:
                job = jobs.get(parts[1])
            except KeyError:
                return self._err(404, "unknown_job", f"no job {parts[1]!r}")
            if job.done() and job.state != JobState.CANCELLED:
                return self._err(409, "already_finished",
                                 f"job {job.id} already {job.state}")
            jobs.cancel(job.id)
            return self._send(202, {"job_id": job.id, "state": job.state})
        return self._err(404, "not_found", "not found")

    # ------------------------------------------------------------------
    def do_POST(self):
        from repro.api import Pipeline
        from repro.api.jobs import JobStoreFull
        from repro.core.recipes import Recipe
        from repro.core.registry import validate_op_config

        url = urlparse(self.path)
        qs = parse_qs(url.query)
        parts = [p for p in url.path.split("/") if p]
        try:
            params = self._read_body()
        except ValueError as e:
            return self._err(400, "malformed_json", f"invalid JSON body: {e}")

        try:
            if parts == ["jobs"]:
                # only path-valued params may come from the query string —
                # typed Recipe fields (np, use_fusion, ...) would arrive as
                # strings and corrupt the run; they belong in the JSON body
                return self._submit_job({**params, **{
                    k: v[0] for k, v in qs.items()
                    if k in ("dataset_path", "export_path")}})

            if parts == ["sql"]:
                return self._run_sql(params, qs)

            dataset_path = qs.get("dataset_path", [None])[0]
            if not dataset_path:
                return self._err(400, "missing_param",
                                 "dataset_path query param required")
            out_path = qs.get("export_path", [dataset_path + ".out.jsonl"])[0]

            if len(parts) == 2 and parts[0] == "run":
                try:
                    validate_op_config({"name": parts[1], **params})
                except KeyError as e:
                    return self._err(404, "unknown_op", str(e.args[0] if e.args else e))
                except TypeError as e:
                    return self._err(400, "invalid_params", str(e))
                # single-op runs lower through the shared Pipeline/plan like
                # every other front-end (no raw op construction here)
                pipe = (Pipeline.read_jsonl(dataset_path)
                        .op(parts[1], **params).write_jsonl(out_path))
                _, report = pipe.execute()
                return self._send(200, {
                    "status": "ok", "export_path": out_path,
                    "n_out": report.n_out, "errors": report.errors,
                })

            if parts == ["process"]:
                recipe = Recipe.from_dict({**params, "dataset_path": dataset_path,
                                           "export_path": out_path})
                try:
                    for cfg in recipe.process:
                        validate_op_config(cfg, strict=False)
                except KeyError as e:
                    return self._err(404, "unknown_op", str(e.args[0] if e.args else e))
                _, report = Pipeline.from_recipe(recipe).execute()
                return self._send(200, {
                    "status": "ok", "export_path": out_path,
                    "n_in": report.n_in, "n_out": report.n_out,
                    "plan": report.plan, "seconds": report.seconds,
                })
        except JobStoreFull as e:
            return self._err(503, "job_store_full", str(e))
        except Exception as e:  # noqa: BLE001
            return self._err(500, "internal", f"{type(e).__name__}: {e}")
        return self._err(404, "not_found", "not found")

    def _run_sql(self, params: dict, qs):
        """POST /sql: compile the query onto the shared logical plan and run
        synchronously. Unknown columns get the /jobs unknown-op treatment —
        404 with did-you-mean ``suggestions``; other rejections are 400."""
        from repro.api.sql import SQLError, parse_sql, sql

        query = params.get("query") or qs.get("query", [None])[0]
        if not query or not isinstance(query, str):
            return self._err(400, "missing_param",
                             "body must contain a 'query' string")
        dataset_path = params.get("dataset_path") \
            or qs.get("dataset_path", [None])[0]
        export_path = params.get("export_path") \
            or qs.get("export_path", [None])[0]
        try:
            q = parse_sql(query)
            base = dataset_path or (q.source if q.source_is_path else None)
            if not base:
                return self._err(400, "missing_param",
                                 "dataset_path required (or quote a path in "
                                 "FROM)")
            out_path = export_path or base + ".out.jsonl"
            pipe = sql(query, dataset_path=base, export_path=out_path)
        except SQLError as e:
            code = 404 if e.kind == "unknown_column" else 400
            return self._send(code, {"error": {
                "type": e.kind, "message": str(e),
                "suggestions": e.suggestions}})
        _, report = pipe.execute()
        return self._send(200, {
            "status": "ok", "export_path": out_path,
            "n_in": report.n_in, "n_out": report.n_out,
            "plan": report.plan, "seconds": report.seconds,
        })

    def _submit_job(self, spec: dict):
        """POST /jobs: validate up front (fail fast with 4xx), then enqueue —
        the handler returns in milliseconds regardless of job duration."""
        from repro.core.recipes import Recipe
        from repro.core.registry import validate_op_config
        from repro.api import Pipeline

        process = spec.get("process")
        if not isinstance(process, list) or not process:
            return self._err(400, "missing_param",
                             "body must contain a non-empty 'process' list")
        if not spec.get("dataset_path"):
            return self._err(400, "missing_param", "dataset_path required")
        try:
            for cfg in process:
                if not isinstance(cfg, dict):
                    raise TypeError(f"op config must be an object, got {cfg!r}")
                validate_op_config(cfg, strict=bool(spec.get("strict", False)))
        except KeyError as e:
            return self._err(404, "unknown_op", str(e.args[0] if e.args else e))
        except TypeError as e:
            return self._err(400, "invalid_params", str(e))

        # tenant identity: X-DJ-API-Key header resolves through the cluster
        # tenants.json key map (unknown key -> 403: never silently misfile a
        # keyed submission under the default tenant); else the body's
        # 'tenant' field; else the default tenant. Single-node mode has no
        # tenant registry — the header is ignored there.
        tenant = spec.get("tenant") or None
        api_key = self.headers.get("X-DJ-API-Key")
        cluster = getattr(self.server.jobs, "cluster", None)
        if api_key and cluster is not None:
            tenant = cluster.tenant_for_key(api_key)
            if tenant is None:
                return self._err(403, "unknown_api_key",
                                 "X-DJ-API-Key does not match any tenant in "
                                 "tenants.json")

        pipe = Pipeline.from_recipe(Recipe.from_dict(
            {k: v for k, v in spec.items() if k != "strict"}))
        try:
            job = self.server.jobs.submit(pipe, tenant=tenant)
        except ValueError as e:
            return self._err(400, "invalid_params", str(e))
        out = {"job_id": job.id, "state": job.state,
               "poll": f"/jobs/{job.id}"}
        if tenant:
            out["tenant"] = tenant
        return self._send(202, out)


def serve(host: str = "127.0.0.1", port: int = 8123,
          max_workers: int = 2, max_jobs: int = 64,
          job_dir: str = None, cluster_dir: str = None) -> DJServer:
    srv = DJServer((host, port), _Handler, max_workers=max_workers,
                   max_jobs=max_jobs, job_dir=job_dir,
                   cluster_dir=cluster_dir)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    return srv
