"""Natural-language interaction (paper §4, Appendix C.4).

Offline ReAct-style loop: a rule-based intent parser maps user requests to
OPs + parameters (the LLM-agent role), executes through the same code path
the RESTful API uses, and reports thought/function/result traces — the
paper's transparency pattern, minus the hosted model.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

_INTENTS: List[Tuple[re.Pattern, str, Dict[str, Any]]] = [
    (re.compile(r"(filter|remove|drop).{0,40}(short|small).{0,20}text", re.I),
     "text_length_filter", {"min_val": 80}),
    (re.compile(r"(filter|remove|drop).{0,40}(long).{0,20}text", re.I),
     "text_length_filter", {"max_val": 10000}),
    (re.compile(r"de-?dup|duplicate", re.I),
     "document_minhash_deduplicator", {"jaccard_threshold": 0.7}),
    (re.compile(r"lower ?case", re.I), "lowercase_mapper", {}),
    (re.compile(r"(remove|strip|clean).{0,20}(html|tags)", re.I), "remove_html_mapper", {}),
    (re.compile(r"(remove|clean).{0,20}(link|url)", re.I), "clean_links_mapper", {}),
    (re.compile(r"(remove|clean).{0,20}e-?mail", re.I), "clean_email_mapper", {}),
    (re.compile(r"nsfw|not.?safe", re.I), "image_nsfw_filter", {"threshold": 0.5}),
    (re.compile(r"quality", re.I), "quality_score_filter", {"min_val": 0.4}),
    (re.compile(r"normali[sz]e.{0,20}(whitespace|spaces)", re.I),
     "whitespace_normalization_mapper", {}),
    (re.compile(r"motion", re.I), "video_motion_score_filter", {"min_val": 0.1}),
]

_NUM_RE = re.compile(r"(min(?:imum)?|max(?:imum)?|threshold)\D{0,15}?([\d.]+)", re.I)


@dataclasses.dataclass
class AgentTurn:
    thought: str
    function: Optional[str]
    arguments: Dict[str, Any]
    result: Optional[dict] = None


def parse_intent(request: str) -> List[AgentTurn]:
    turns: List[AgentTurn] = []
    for pat, op, defaults in _INTENTS:
        if pat.search(request):
            args = dict(defaults)
            for key, val in _NUM_RE.findall(request):
                k = key.lower()
                v = float(val)
                if k.startswith("min"):
                    args["min_val"] = v
                elif k.startswith("max"):
                    args["max_val"] = v
                else:
                    args["threshold"] = v
            turns.append(AgentTurn(
                thought=f"request matches '{pat.pattern[:40]}...' -> use {op}",
                function=op, arguments=args,
            ))
    if not turns:
        turns.append(AgentTurn(
            thought="no OP intent recognised; ask the user to rephrase",
            function=None, arguments={},
        ))
    return turns


def run_request(request: str, dataset) -> Tuple[Any, List[AgentTurn]]:
    """Interprets the request and executes the matched OPs on the dataset."""
    from repro.core.registry import create_op

    turns = parse_intent(request)
    ds = dataset
    for t in turns:
        if t.function is None:
            continue
        op = create_op({"name": t.function, **t.arguments})
        n0 = len(ds)
        ds = ds.process(op)
        t.result = {"status": "SUCCESS", "in": n0, "out": len(ds)}
    return ds, turns
