"""Natural-language interaction (paper §4, Appendix C.4).

Offline ReAct-style loop: a rule-based intent parser maps user requests to
OPs + parameters (the LLM-agent role) and *emits a lazy Pipeline* — i.e. it
lowers onto the same logical-plan IR (repro.core.plan) every other front-end
(CLI recipes, REST, SQL) compiles to — so conversational
requests get fusion, reordering and streaming execution for free, and the
thought/function/result trace (the paper's transparency pattern) reports the
optimized plan that actually ran.

Numeric binding is span-aware: each number in the request is bound to the
*nearest* matched intent that accepts it ("drop short text under 50 and
dedup at threshold 0.8" no longer cross-contaminates both OPs' args).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

_INTENTS: List[Tuple[re.Pattern, str, Dict[str, Any]]] = [
    (re.compile(r"(filter|remove|drop).{0,40}(short|small).{0,20}text", re.I),
     "text_length_filter", {"min_val": 80}),
    (re.compile(r"(filter|remove|drop).{0,40}(long).{0,20}text", re.I),
     "text_length_filter", {"max_val": 10000}),
    (re.compile(r"de-?dup|duplicate", re.I),
     "document_minhash_deduplicator", {"jaccard_threshold": 0.7}),
    (re.compile(r"lower ?case", re.I), "lowercase_mapper", {}),
    (re.compile(r"(remove|strip|clean).{0,20}(html|tags)", re.I), "remove_html_mapper", {}),
    (re.compile(r"(remove|clean).{0,20}(link|url)", re.I), "clean_links_mapper", {}),
    (re.compile(r"(remove|clean).{0,20}e-?mail", re.I), "clean_email_mapper", {}),
    (re.compile(r"nsfw|not.?safe", re.I), "image_nsfw_filter", {"threshold": 0.5}),
    (re.compile(r"quality", re.I), "quality_score_filter", {"min_val": 0.4}),
    (re.compile(r"normali[sz]e.{0,20}(whitespace|spaces)", re.I),
     "whitespace_normalization_mapper", {}),
    (re.compile(r"motion", re.I), "video_motion_score_filter", {"min_val": 0.1}),
]

_NUM_RE = re.compile(r"(min(?:imum)?|max(?:imum)?|threshold)\D{0,15}?([\d.]+)", re.I)
_BARE_NUM_RE = re.compile(r"\d+(?:\.\d+)?")
# a bare (keyword-less) number further than this from every intent anchor is
# probably incidental ("my 3 corpora") and stays unbound
_BARE_GAP_LIMIT = 60


def _plausible(param: str, val: float) -> bool:
    """Range sanity for implicit bindings: a similarity threshold outside
    (0, 1] would silently turn the op into a no-op."""
    if "threshold" in param:
        return 0.0 < val <= 1.0
    return val >= 0


@dataclasses.dataclass
class AgentTurn:
    thought: str
    function: Optional[str]
    arguments: Dict[str, Any]
    result: Optional[dict] = None


def _accepted_params(op_name: str) -> set:
    from repro.core.registry import op_signature

    try:
        return {p["name"] for p in op_signature(op_name)["params"]}
    except KeyError:
        return set()


def _resolve_key(op_name: str, defaults: Dict[str, Any], key: Optional[str]) -> Optional[str]:
    """Map a request keyword (min/max/threshold, or a bare number) onto the
    parameter the target OP actually accepts (typed registry signatures)."""
    accepted = _accepted_params(op_name)
    if key is None:  # bare number -> the intent's primary numeric default
        for k, v in defaults.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                return k
        return None
    if key.startswith("min"):
        cand = "min_val"
    elif key.startswith("max"):
        cand = "max_val"
    else:  # "threshold" — e.g. minhash dedup takes jaccard_threshold
        cand = "jaccard_threshold" if "jaccard_threshold" in accepted else "threshold"
    return cand if cand in accepted else None


def _span_gap(a: Tuple[int, int], b: Tuple[int, int]) -> int:
    if a[0] < b[1] and b[0] < a[1]:  # overlap
        return 0
    return b[0] - a[1] if b[0] >= a[1] else a[0] - b[1]


def parse_intent(request: str) -> List[AgentTurn]:
    hits: List[Tuple[Tuple[int, int], str, Dict[str, Any]]] = []
    for pat, op, defaults in _INTENTS:
        m = pat.search(request)
        if m:
            hits.append((m.span(), op, dict(defaults)))
    if not hits:
        return [AgentTurn(
            thought="no OP intent recognised; ask the user to rephrase",
            function=None, arguments={},
        )]
    hits.sort(key=lambda h: h[0][0])  # pipeline order = mention order

    # numbers: keyword-qualified first, then bare numbers not already consumed
    numbers: List[Tuple[Tuple[int, int], Optional[str], float]] = []
    consumed: List[Tuple[int, int]] = []
    for m in _NUM_RE.finditer(request):
        numbers.append((m.span(), m.group(1).lower(), float(m.group(2))))
        consumed.append(m.span())
    for m in _BARE_NUM_RE.finditer(request):
        if any(m.start() >= s and m.end() <= e for s, e in consumed):
            continue
        numbers.append((m.span(), None, float(m.group())))

    bindings: List[str] = []
    keyword_bound = set()  # (id(args), param) pairs set by qualified numbers
    for span, key, val in numbers:
        # nearest intent that accepts the resolved param; an intent mentioned
        # BEFORE the number wins over a closer one mentioned after it ("drop
        # short text under 50 and dedup ..." -> 50 belongs to the text filter)
        candidates = []
        for hit_span, op, args in hits:
            k = _resolve_key(op, args, key)
            if k is None:
                continue
            if key is None and (id(args), k) in keyword_bound:
                continue  # bare numbers never override qualified ones
            follows = hit_span[0] <= span[0]
            # bare numbers measure from the intent's ANCHOR (match start):
            # a greedy intent regex can span the whole request, and span
            # overlap would then steal numbers from nearer intents
            gap = abs(span[0] - hit_span[0]) if key is None \
                else _span_gap(hit_span, span)
            if key is None and (gap > _BARE_GAP_LIMIT
                                or not _plausible(k, val)):
                continue
            candidates.append((not follows, gap, args, k, op))
        if candidates:
            _, _, args, k, op = min(candidates, key=lambda c: c[:2])
            args[k] = val
            if key is not None:
                keyword_bound.add((id(args), k))
            bindings.append(f"{val:g}->{op}.{k}")

    turns = []
    for span, op, args in hits:
        note = "; bound " + ", ".join(b for b in bindings if f"->{op}." in b) \
            if any(f"->{op}." in b for b in bindings) else ""
        turns.append(AgentTurn(
            thought=f"request span {span} -> use {op}{note}",
            function=op, arguments=args,
        ))
    return turns


def build_pipeline(request: str, source=None) -> Tuple[Any, List[AgentTurn]]:
    """Emit a lazy Pipeline for the request (the NL front-end's compile
    step). ``source`` is a DJDataset, a JSONL path, or None (attach later
    via pipeline composition)."""
    from repro.api import Pipeline

    turns = parse_intent(request)
    if source is None:
        pipe = Pipeline()
    elif isinstance(source, str):
        pipe = Pipeline.read_jsonl(source)
    else:
        pipe = Pipeline.from_dataset(source)
    for t in turns:
        if t.function is not None:
            pipe = pipe.op(t.function, **t.arguments)
    return pipe, turns


def run_request(request: str, dataset) -> Tuple[Any, List[AgentTurn]]:
    """Interpret the request, lower it to one Pipeline, and execute it once
    through the shared Executor path (fusion/streaming included)."""
    pipe, turns = build_pipeline(request, dataset)
    if not any(t.function for t in turns):
        return dataset, turns

    ds, report = pipe.execute()
    # map the optimized plan's per-op rows back onto the agent turns: exact
    # rows are consumed once each (two instances of the same op keep their
    # own counts), fused rows are shared by every member op
    used = set()
    for t in turns:
        if t.function is None:
            continue
        row = None
        for idx, r in enumerate(report.per_op):
            if idx not in used and r["op"] == t.function:
                row = r
                used.add(idx)
                break
        if row is None:
            row = next((r for r in report.per_op if t.function in r["op"]), None)
        if row is not None:
            t.result = {"status": "SUCCESS", "in": row["in"], "out": row["out"],
                        "via": row["op"]}
        else:
            t.result = {"status": "SUCCESS", "in": report.n_in, "out": report.n_out}
    return ds, turns
