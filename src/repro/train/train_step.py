"""Train / serve step factories shared by the launcher, dry-run and examples.

``make_train_step`` builds a pjit-able (state, batch) -> (state, metrics)
function with optional microbatched gradient accumulation (activation-memory
knob) and the AdamW update from ``repro.train.optimizer``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import module as mod
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    n_microbatches: int = 1
    grad_dtype: Any = jnp.float32  # accumulation dtype across microbatches
    # mixed precision: store working params in bf16 (halves FSDP all-gather
    # and gradient-reduction bytes) with an f32 master copy updated by AdamW
    bf16_params: bool = False


def state_specs(model, cfg: TrainConfig = TrainConfig()) -> Dict[str, Any]:
    """ParamSpec tree for the full TrainState {params[, master], m, v, step}."""
    p = model.param_specs()
    f32 = lambda s: mod.ParamSpec(s.shape, s.axes, jnp.float32, "zeros")
    out: Dict[str, Any] = {
        "params": p,
        "m": mod.tree_map_specs(f32, p),
        "v": mod.tree_map_specs(f32, p),
        "step": mod.spec((), (), jnp.int32, "zeros"),
    }
    if cfg.bf16_params:
        bf16 = lambda s: mod.ParamSpec(s.shape, s.axes, jnp.bfloat16, s.init, s.scale)
        out["params"] = mod.tree_map_specs(bf16, p)
        out["master"] = mod.tree_map_specs(f32, p)
    return out


def init_state(model, key, opt_cfg: OptConfig = OptConfig(), cfg: Optional[TrainConfig] = None):
    params = model.init_params(key)
    st = init_opt_state(params, opt_cfg)
    if cfg is not None and cfg.bf16_params:
        master = params
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
        return {"params": params, "master": master, **st}
    return {"params": params, **st}


def _split_microbatches(batch, n: int):
    """Split the global batch into n microbatches WITHOUT resharding.

    Layout (b//n, n, ...) -> transpose keeps each device's contiguous batch
    rows local: device d's rows become (d, 0..n-1), so every microbatch
    stays evenly sharded over the data axis with zero communication.
    """

    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n,))
        assert x.shape[0] % n == 0, (x.shape, n)
        return x.reshape(x.shape[0] // n, n, *x.shape[1:]).swapaxes(0, 1)

    return jax.tree.map(split, batch)


def make_train_step(model, cfg: TrainConfig = TrainConfig()):
    def train_step(state, batch):
        params = state["params"]

        def loss_fn(p, b):
            return model.loss_fn(p, b)

        if cfg.n_microbatches > 1:
            micro = _split_microbatches(batch, cfg.n_microbatches)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g = jax.tree.map(lambda a, b: a + b.astype(cfg.grad_dtype), g_acc, g)
                return (g, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, cfg.grad_dtype), params
            )
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, 0.0), micro)
            inv = 1.0 / cfg.n_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            metrics: Dict[str, jax.Array] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )

        opt_state = {"m": state["m"], "v": state["v"], "step": state["step"]}
        if cfg.bf16_params:
            new_master, new_opt, gnorm = adamw_update(
                state["master"], grads, opt_state, cfg.opt
            )
            new_p = jax.tree.map(lambda p: p.astype(jnp.bfloat16), new_master)
            new_state = {"params": new_p, "master": new_master, **new_opt}
        else:
            new_p, new_opt, gnorm = adamw_update(params, grads, opt_state, cfg.opt)
            new_state = {"params": new_p, **new_opt}
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm,
            **{k: v for k, v in metrics.items()},
        }
        return new_state, out_metrics

    return train_step
