"""AdamW from scratch (optax is not available offline).

Optimizer state (m, v) mirrors the parameter tree — same shapes, same
logical axes — so ZeRO-style sharding of the optimizer falls out of the
parameter rules. ``state_dtype`` can be lowered to bf16 to halve optimizer
memory (a distributed-optimization knob used in §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32


def init_opt_state(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(
    params, grads, opt_state, cfg: OptConfig
) -> Tuple[Any, Any, jax.Array]:
    """Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * delta
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
