"""Training-side checkpoint/restart (+ elastic data-parallel resume).

Numpy-npz based (no orbax offline): the state pytree is flattened to
path-keyed arrays, written atomically, and restored onto any mesh — the
restore path re-shards via ``jax.device_put`` with the target shardings, so
restarts can change the data-parallel width (elastic scaling).
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_state(path: str, state) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(state)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)


def load_state(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (ShapeDtypeStructs or arrays);
    optionally placing shards per ``shardings`` (elastic re-shard)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    leaves_like, _ = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_keys, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(arr)
    tree = jax.tree.unflatten(jax.tree.structure(like), out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def save_params(path: str, params) -> None:
    save_state(path, params)


def load_params(path: str, like=None):
    if like is None:
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    return load_state(path, like)
