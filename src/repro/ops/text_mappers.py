"""Text Mapper OPs (editing / cleaning / synthesis-lite)."""
from __future__ import annotations

import re
import time
from typing import List

from repro.core.ops_base import Mapper
from repro.core.registry import register

_HTML_RE = re.compile(r"<[^>]{1,200}>")
_LINK_RE = re.compile(r"https?://\S+|www\.\S+")
_EMAIL_RE = re.compile(r"[\w.+-]+@[\w-]+\.[\w.-]+")
_WS_RE = re.compile(r"[ \t\f\v]+")
_REPEAT_RE = re.compile(r"(.)\1{7,}")


def _set_text(sample, text):
    sample = dict(sample)
    sample["text"] = text
    return sample


@register("whitespace_normalization_mapper")
class WhitespaceNormalizationMapper(Mapper):
    """Collapses runs of spaces/tabs; trims trailing space per line."""

    def process_single(self, s):
        t = "\n".join(_WS_RE.sub(" ", l).rstrip() for l in s.get("text", "").splitlines())
        return _set_text(s, t)


@register("remove_html_mapper")
class RemoveHtmlMapper(Mapper):
    """Strips HTML tags."""

    def process_single(self, s):
        return _set_text(s, _HTML_RE.sub(" ", s.get("text", "")))


@register("clean_links_mapper")
class CleanLinksMapper(Mapper):
    """Removes URLs."""

    def process_single(self, s):
        return _set_text(s, _LINK_RE.sub("", s.get("text", "")))


@register("clean_email_mapper")
class CleanEmailMapper(Mapper):
    """Removes e-mail addresses (privacy OP family)."""

    def process_single(self, s):
        return _set_text(s, _EMAIL_RE.sub("", s.get("text", "")))


@register("remove_repeat_chars_mapper")
class RemoveRepeatCharsMapper(Mapper):
    """Caps absurd character runs (aaaaaaaa... -> aaa)."""

    def process_single(self, s):
        return _set_text(s, _REPEAT_RE.sub(lambda m: m.group(1) * 3, s.get("text", "")))


@register("lowercase_mapper")
class LowercaseMapper(Mapper):
    """Lower-cases text."""

    def process_single(self, s):
        return _set_text(s, s.get("text", "").lower())


@register("fix_unicode_mapper")
class FixUnicodeMapper(Mapper):
    """Drops control chars / replacement chars, normalises newlines."""

    def process_single(self, s):
        t = s.get("text", "").replace("\r\n", "\n").replace("\r", "\n")
        t = "".join(c for c in t if c == "\n" or c == "\t" or ord(c) >= 32)
        return _set_text(s, t.replace("�", ""))


@register("sentence_split_mapper")
class SentenceSplitMapper(Mapper):
    """1->many: splits a document into per-sentence samples."""

    expands = True
    _SENT_RE = re.compile(r"(?<=[.!?])\s+")

    def process_single(self, s):
        sents = [x for x in self._SENT_RE.split(s.get("text", "")) if x.strip()]
        out = []
        for sent in sents or [""]:
            ns = dict(s)
            ns["text"] = sent
            ns["meta"] = dict(s.get("meta", {}), parent_len=len(s.get("text", "")))
            out.append(ns)
        return out


@register("dedup_lines_mapper")
class DedupLinesMapper(Mapper):
    """Removes exact duplicate lines within a document."""

    def process_single(self, s):
        seen = set()
        out: List[str] = []
        for l in s.get("text", "").splitlines():
            key = l.strip()
            if key and key in seen:
                continue
            seen.add(key)
            out.append(l)
        return _set_text(s, "\n".join(out))


@register("sentence_augmentation_mapper")
class SentenceAugmentationMapper(Mapper):
    """Deterministic augmentation: drops a seeded fraction of words
    (the paper's LLM-based variant adapted to an offline rule)."""

    def __init__(self, drop_rate: float = 0.1, seed: int = 0, **kw):
        super().__init__(drop_rate=drop_rate, seed=seed, **kw)
        self.drop_rate = drop_rate
        self.seed = seed

    def process_single(self, s):
        import numpy as np

        words = s.get("text", "").split()
        rng = np.random.default_rng(self.seed + len(words))
        keep = rng.random(len(words)) >= self.drop_rate
        return _set_text(s, " ".join(w for w, k in zip(words, keep) if k))


@register("sleep_mapper")
class SleepMapper(Mapper):
    """Identity mapper that sleeps ``delay`` seconds per sample.

    Pacing / fault-injection utility: makes runs long enough to observe live
    progress, exercise speculative re-dispatch and preemption, and (in the
    cluster test harness) guarantee a runner can be killed mid-job. The small
    default batch keeps the chain runner's preemption poll responsive."""

    default_batch_size = 4

    def __init__(self, delay: float = 0.01, **kw):
        super().__init__(delay=delay, **kw)
        self.delay = max(0.0, float(delay))

    def process_single(self, s):
        if self.delay:
            time.sleep(self.delay)
        return s


@register("generate_qa_from_text_mapper")
class GenerateQAFromTextMapper(Mapper):
    """Synthesis OP: turns declarative sentences into (query, response)
    post-tuning samples (template-based offline stand-in for the LLM OP)."""

    expands = True
    _SENT_RE = re.compile(r"(?<=[.!?])\s+")

    def process_single(self, s):
        out = []
        for sent in self._SENT_RE.split(s.get("text", "")):
            words = sent.split()
            if len(words) < 4:
                continue
            subject = " ".join(words[:3])
            q = f"What can you tell me about {subject.rstrip('.,!?')}?"
            ns = dict(s)
            ns.update(text="", query=q, response=sent.strip(), history=[])
            ns["meta"] = dict(s.get("meta", {}), synthesized=True)
            out.append(ns)
        return out or [dict(s)]


@register("select_fields_mapper")
class SelectFieldsMapper(Mapper):
    """Projection: keeps only the listed top-level sample fields (how SQL
    ``SELECT col, ...`` narrows the exported rows)."""

    def __init__(self, fields=("text",), **kw):
        super().__init__(fields=tuple(fields), **kw)
        self.fields = tuple(fields)

    def process_single(self, s):
        return {k: s[k] for k in self.fields if k in s}
