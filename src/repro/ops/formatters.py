"""Formatter OPs: raw records -> schema samples."""
from __future__ import annotations

from repro.core import schema as S
from repro.core.ops_base import Formatter
from repro.core.registry import register


@register("text_formatter")
class TextFormatter(Formatter):
    """{text_key: ...} records -> schema samples."""

    def __init__(self, text_key: str = "text", **kw):
        super().__init__(text_key=text_key, **kw)

    def format_single(self, rec):
        s = S.new_sample(str(rec.get(self.params["text_key"], "")))
        s["meta"] = {k: v for k, v in rec.items() if k != self.params["text_key"]
                     and isinstance(v, (str, int, float, bool))}
        return s


@register("alpaca_formatter")
class AlpacaFormatter(Formatter):
    """Alpaca instruction records -> post-tuning schema samples."""

    def format_single(self, rec):
        return S.from_alpaca(rec)
