"""Deduplicator OPs: exact hash + MinHash-LSH (standalone & parallel)."""
from __future__ import annotations

import hashlib
from typing import List

import numpy as np

from repro.core.dedup.minhash import minhash_dedup_indices
from repro.core.ops_base import Deduplicator, Mapper
from repro.core.registry import register

# sample-level carriers for worker-computed signatures; the streaming dedup
# stage pops them before samples reach any sink/observer
MH_DOC_KEY = "__mh_doc__"
MH_SIG_KEY = "__mh_sig__"


@register("minhash_signature_mapper")
class MinHashSignatureMapper(Mapper):
    """INTERNAL: worker-side shingle + MinHash-signature precompute for the
    streaming dedup stage. Planted by ``StreamingMinHashState.presign_ops``
    in front of the stateful segment so the embarrassingly-parallel 99% of
    dedup compute rides the engine's pipelined block dispatch (overlapping
    with driver-side band indexing) instead of serializing on the driver.
    Annotates samples in place with numpy arrays under MH_DOC_KEY /
    MH_SIG_KEY — never let these reach an export (the dedup stage strips
    them)."""

    commutative = False  # planted immediately before its dedup stage; pinned

    def __init__(self, num_permutations: int = 128, ngram: int = 5,
                 seed: int = 42, **kw):
        super().__init__(num_permutations=num_permutations, ngram=ngram,
                         seed=seed, **kw)
        self._perm = None

    def setup(self):
        if self._perm is None:
            from repro.core.dedup.minhash import make_permutations

            self._perm = make_permutations(
                self.params["num_permutations"], self.params["seed"])

    def process_batch(self, batch):
        from repro.core.dedup.minhash import shingle_hashes, signature_ref

        a, b = self._perm
        for s in batch:
            d = shingle_hashes(s.get("text", ""), n=self.params["ngram"])
            # signature from the RAW shingles (bit-exact with the barriered
            # path); ship the uniqued array — Jaccard has set semantics, and
            # unique halves the bytes crossing the worker boundary
            s[MH_SIG_KEY] = signature_ref(d, a, b)
            s[MH_DOC_KEY] = np.unique(d)
        return batch

    # -- columnar path -----------------------------------------------------
    def supports_columns(self):
        return True

    def process_columns(self, block):
        from repro.core.dedup.minhash import shingle_hashes, signature_ref

        a, b = self._perm
        n = self.params["ngram"]
        sigs, docs = [], []
        for t in block.string_values("text"):
            d = shingle_hashes(t, n=n)
            sigs.append(signature_ref(d, a, b))
            docs.append(np.unique(d))
        # same key order the row path produces: sig first, doc second
        return (block.with_py_column(MH_SIG_KEY, sigs)
                     .with_py_column(MH_DOC_KEY, docs))


@register("exact_text_deduplicator")
class ExactTextDeduplicator(Deduplicator):
    """Document-level exact dedup by text digest."""

    def dedup(self, samples):
        seen = set()
        out = []
        for s in samples:
            h = hashlib.blake2b(s.get("text", "").encode("utf-8"), digest_size=16).digest()
            if h in seen:
                continue
            seen.add(h)
            out.append(s)
        return out


@register("document_minhash_deduplicator")
class DocumentMinHashDeduplicator(Deduplicator):
    """MinHash-LSH fuzzy dedup (paper's minhash_deduplicator; engine-agnostic
    algorithm parameters: jaccard_threshold / num_permutations).

    ``streaming`` selects the execution protocol under the streaming
    executor (``repro.core.dedup.streaming``):

    * ``"off"`` (default) — dataset barrier, exact batch result.
    * ``"keep_first"`` — single-pass incremental stage: blocks flow through,
      O(index) resident memory; keeps a documented *superset* of the exact
      result (retroactive component merges can't retract emitted docs).
    * ``"windowed"`` — keep_first with a bounded retroactive-merge horizon:
      each doc's keep/drop decision waits until ``window`` newer docs have
      arrived, honoring merges bridged inside the horizon. Keep sets nest
      ``exact ⊆ windowed ⊆ keep_first``; memory O(index + window).
    * ``"exact"`` — two-pass incremental stage: pass 1 spills samples to
      disk while building the pair registry, finalize replays with final
      components — byte-identical to the barriered result, still bounded
      resident memory.

    ``super_batch`` sizes the cross-block signature super-batches,
    ``spill_dir`` hosts the shingle/sample spill files (tmpdir by default).
    """

    def __init__(self, jaccard_threshold: float = 0.7, num_permutations: int = 128,
                 num_bands: int = 16, ngram: int = 5, backend: str = "balanced",
                 n_partitions: int = 8, use_kernel: bool = False,
                 streaming: str = "off", window: int = 4096,
                 super_batch: int = 2048, spill_dir: str = None, **kw):
        if streaming not in ("off", "keep_first", "windowed", "exact"):
            raise ValueError(
                "streaming must be 'off', 'keep_first', 'windowed' or "
                f"'exact', got {streaming!r}")
        super().__init__(
            jaccard_threshold=jaccard_threshold, num_permutations=num_permutations,
            num_bands=num_bands, ngram=ngram, backend=backend,
            n_partitions=n_partitions, use_kernel=use_kernel,
            streaming=streaming, window=window, super_batch=super_batch,
            spill_dir=spill_dir, **kw)

    def supports_streaming(self) -> bool:
        return self.params.get("streaming", "off") in (
            "keep_first", "windowed", "exact")

    def streaming_state(self):
        from repro.core.dedup.streaming import StreamingMinHashState

        p = self.params
        return StreamingMinHashState(
            n_perm=p["num_permutations"], n_bands=p["num_bands"],
            ngram=p["ngram"], jaccard_threshold=p["jaccard_threshold"],
            backend=p["backend"], n_partitions=p["n_partitions"],
            use_kernel=p["use_kernel"], exact=p["streaming"] == "exact",
            windowed=p["streaming"] == "windowed", window=p["window"],
            super_batch=p["super_batch"], spill_dir=p["spill_dir"])

    def dedup(self, samples):
        p = self.params
        keep, comp = minhash_dedup_indices(
            [s.get("text", "") for s in samples],
            n_perm=p["num_permutations"], n_bands=p["num_bands"], ngram=p["ngram"],
            jaccard_threshold=p["jaccard_threshold"], backend=p["backend"],
            n_partitions=p["n_partitions"], use_kernel=p["use_kernel"],
        )
        out = []
        for s, k, c in zip(samples, keep, comp):
            if k:
                s.setdefault("stats", {})["dup_component"] = int(c)
                out.append(s)
        return out


@register("streaming_minhash_deduplicator")
class StreamingMinHashDeduplicator(DocumentMinHashDeduplicator):
    """Streaming-first registration of MinHash dedup: identical algorithm,
    but defaults to the incremental keep-first pipeline stage so recipes /
    Pipelines / REST jobs opt into streaming dedup by op name alone.
    (Full signature restated so typed-signature kwarg validation keeps
    accepting the algorithm parameters.)"""

    def __init__(self, jaccard_threshold: float = 0.7, num_permutations: int = 128,
                 num_bands: int = 16, ngram: int = 5, backend: str = "balanced",
                 n_partitions: int = 8, use_kernel: bool = False,
                 streaming: str = "keep_first", window: int = 4096,
                 super_batch: int = 2048, spill_dir: str = None, **kw):
        super().__init__(
            jaccard_threshold=jaccard_threshold, num_permutations=num_permutations,
            num_bands=num_bands, ngram=ngram, backend=backend,
            n_partitions=n_partitions, use_kernel=use_kernel, streaming=streaming,
            window=window, super_batch=super_batch, spill_dir=spill_dir, **kw)


@register("shard_minhash_map")
class ShardMinHashMapper(Deduplicator):
    """INTERNAL: the map phase of a sharded dedup job (``repro.api.shards``).

    Planted by the lead runner as the stateful tail of each shard's pinned
    plan: runs over one contiguous row range, presigns locally (same carrier
    protocol as the single-runner stage), spills the post-prefix rows
    byte-identically to the single-runner exact spill, and routes band keys
    + uniqued shingles to their band owners via the shared store
    (``shard_dir``). Emits NO samples — the reduce/finalize tasks consume
    its published files. Never plant this op by hand."""

    commutative = False

    def __init__(self, shard_index: int = 0, n_shards: int = 1,
                 n_reducers: int = 1, shard_dir: str = None,
                 num_permutations: int = 128, num_bands: int = 16,
                 ngram: int = 5, seed: int = 42, use_kernel: bool = False,
                 super_batch: int = 2048, **kw):
        super().__init__(
            shard_index=shard_index, n_shards=n_shards, n_reducers=n_reducers,
            shard_dir=shard_dir, num_permutations=num_permutations,
            num_bands=num_bands, ngram=ngram, seed=seed, use_kernel=use_kernel,
            super_batch=super_batch, **kw)

    def supports_streaming(self) -> bool:
        return True

    def streaming_state(self):
        from repro.core.dedup.sharded import ShardMapState

        p = self.params
        return ShardMapState(
            shard_index=p["shard_index"], n_shards=p["n_shards"],
            n_reducers=p["n_reducers"], shard_dir=p["shard_dir"],
            n_perm=p["num_permutations"], n_bands=p["num_bands"],
            ngram=p["ngram"], seed=p["seed"], use_kernel=p["use_kernel"],
            super_batch=p["super_batch"])

    def dedup(self, samples):
        # barriered fallback (non-streaming executor): drive the map state
        # over one block; side effects land in shard_dir, nothing is emitted
        from repro.core.storage import SampleBlock

        state = self.streaming_state()
        for _ in state.stream_blocks(iter([SampleBlock(list(samples), nbytes=0)])):
            pass
        return []


@register("distributed_minhash_deduplicator")
class DistributedMinHashDeduplicator(DocumentMinHashDeduplicator):
    """RayDeduplicator analogue: signatures computed by a worker pool over
    pre-split chunks; candidate edges merged through the load-balanced
    partitioned union-find (paper §E.1 — 3.3x over the vanilla path)."""

    def __init__(self, n_workers: int = 4, **kw):
        super().__init__(**kw)
        self.params["n_workers"] = n_workers

    def dedup(self, samples):
        import concurrent.futures as cf

        from repro.core.dedup import minhash as MH
        from repro.core.dedup.unionfind import naive_components, partitioned_union

        p = self.params
        texts = [s.get("text", "") for s in samples]
        n_workers = max(1, int(p["n_workers"]))
        chunk = max(1, len(texts) // (n_workers * 4))
        chunks = [texts[i : i + chunk] for i in range(0, len(texts), chunk)]

        def sig_chunk(args):
            idx, txts = args
            docs = [MH.shingle_hashes(t, n=p["ngram"]) for t in txts]
            sigs = MH.signatures_batch(docs, n_perm=p["num_permutations"])
            return idx, docs, sigs

        docs: List[np.ndarray] = [None] * len(texts)  # type: ignore[list-item]
        sigs = np.empty((len(texts), p["num_permutations"]), np.uint32)
        with cf.ThreadPoolExecutor(n_workers) as pool:
            for idx, dch, sch in pool.map(
                sig_chunk, [(i * chunk, c) for i, c in enumerate(chunks)]
            ):
                for j, d in enumerate(dch):
                    docs[idx + j] = d
                sigs[idx : idx + len(dch)] = sch

        keys = MH.lsh_bands(sigs, p["num_bands"])
        pairs = MH.candidate_pairs_hash_agg(keys)
        if p["jaccard_threshold"] > 0:
            pairs = [(a, b) for a, b in pairs
                     if MH.jaccard(docs[a], docs[b]) >= p["jaccard_threshold"]]
        if p["backend"] == "naive":
            comp = naive_components(len(texts), pairs)
        else:
            comp = partitioned_union(len(texts), pairs, p["n_partitions"]).components()
        seen = set()
        out = []
        for i, s in enumerate(samples):
            c = int(comp[i])
            if c in seen:
                continue
            seen.add(c)
            s.setdefault("stats", {})["dup_component"] = c
            out.append(s)
        return out
