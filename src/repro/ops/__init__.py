"""Builtin operator library. Importing this module registers all OPs."""
from repro.ops import (  # noqa: F401
    aggregators,
    dedup_ops,
    formatters,
    groupers,
    model_ops,
    multimodal_ops,
    post_tuning_ops,
    selectors,
    text_filters,
    text_mappers,
)
