"""Model-based OPs — data processing WITH foundation models (paper §3).

``lm_perplexity_filter`` scores samples with a real JAX LM from the model
substrate (jit-compiled batched scoring on whatever devices/mesh are
available) — the first-class integration between the Data-Juicer runtime
and the training stack. ``ngram_perplexity_filter`` is the cheap rule-based
counterpart (fit on the corpus itself), mirroring the paper's observation
that model-based scoring complements rule-based scoring.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from repro.core.ops_base import Filter, Mapper, shared_words
from repro.core.registry import register
from repro.ops.text_filters import _RangeFilter


@register("ngram_perplexity_filter")
class NgramPerplexityFilter(_RangeFilter):
    """Bigram perplexity under a model fit on the probe corpus (rule-based
    quality proxy; high ppl = unusual/noisy text)."""

    stat_key = "ngram_ppl"

    def __init__(self, min_val=0.0, max_val=math.inf, vocab: int = 1 << 15, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)
        self.vocab = vocab
        self._uni: Optional[np.ndarray] = None
        self._bi: Optional[dict] = None

    def _ids(self, text: str) -> List[int]:
        import hashlib

        return [
            int.from_bytes(hashlib.blake2b(w.lower().encode(), digest_size=4).digest(), "little")
            % self.vocab
            for w in text.split()
        ]

    def _ids_sample(self, s) -> List[int]:
        import hashlib

        return [
            int.from_bytes(hashlib.blake2b(w.lower().encode(), digest_size=4).digest(), "little")
            % self.vocab
            for w in shared_words(s)
        ]

    def fit(self, texts: List[str]) -> None:
        uni = np.ones(self.vocab, np.float64)  # add-one smoothing
        bi: dict = {}
        for t in texts:
            ids = self._ids(t)
            for a in ids:
                uni[a] += 1
            for a, b in zip(ids, ids[1:]):
                bi[(a, b)] = bi.get((a, b), 0) + 1
        self._uni, self._bi = uni / uni.sum(), bi

    def setup(self):
        if self._uni is None:
            self._uni = np.full(self.vocab, 1.0 / self.vocab)
            self._bi = {}

    def _stat(self, s):
        self.setup()
        ids = self._ids_sample(s)
        if len(ids) < 2:
            return 0.0
        logp = 0.0
        for a, b in zip(ids, ids[1:]):
            c_ab = self._bi.get((a, b), 0)
            c_a = self._uni[a] * self.vocab  # un-normalised-ish
            p = (c_ab + 0.5) / (c_a + 0.5 * self.vocab)
            logp += math.log(max(p, 1e-12))
        return float(math.exp(-logp / (len(ids) - 1)))


@register("lm_perplexity_filter")
class LMPerplexityFilter(_RangeFilter):
    """Perplexity from a JAX LM (model substrate), batched + jit'd.

    ``arch`` picks any assigned architecture (reduced config by default so
    the OP runs on CPU); ``params_path`` can point at a trained checkpoint
    (e.g. produced by examples/train_e2e.py — data-model co-development).
    """

    stat_key = "lm_ppl"
    uses_model = True
    gpu_mem_required = 4 << 30
    default_batch_size = 64

    def __init__(self, min_val=0.0, max_val=math.inf, arch: str = "mamba2-1.3b",
                 reduced: bool = True, params_path: str = "", seq_len: int = 128, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)
        self.params.update(arch=arch, reduced=reduced, params_path=params_path,
                           seq_len=seq_len)
        self._model = None
        self._params = None
        self._tok = None
        self._score = None

    def setup(self):
        if self._model is not None:
            return
        import jax
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.data.tokenizer import HashWordTokenizer
        from repro.models.model_zoo import build_model

        cfg = get_config(self.params["arch"], reduced=self.params["reduced"])
        self._model = build_model(cfg, remat_policy="none")
        if self.params["params_path"]:
            from repro.train.checkpointing import load_params

            self._params = load_params(self.params["params_path"])
        else:
            self._params = self._model.init_params(jax.random.PRNGKey(0))
        self._tok = HashWordTokenizer(cfg.vocab_size)
        seq = self.params["seq_len"]

        def score_one(params, tokens, labels, mask):
            loss, _ = self._model.loss_fn(
                params,
                {"tokens": tokens[None], "labels": labels[None], "loss_mask": mask[None]},
            )
            return loss

        self._score = jax.jit(score_one)
        # batched scoring: ONE jit call for the whole batch (vmap over samples)
        self._score_batch = jax.jit(jax.vmap(score_one, in_axes=(None, 0, 0, 0)))
        self._seq = seq

    def _ppl_batch(self, texts: List[str]) -> np.ndarray:
        self.setup()
        import jax.numpy as jnp

        seq = self._seq
        toks = np.zeros((len(texts), seq), np.int32)
        mask = np.zeros((len(texts), seq), np.float32)
        for i, t in enumerate(texts):
            ids = self._tok.encode(t)[: seq + 1]
            n = max(len(ids) - 1, 1)
            toks[i, :n] = ids[:-1][:seq] if len(ids) > 1 else [0]
            mask[i, :n] = 1.0
        labels = np.zeros_like(toks)
        labels[:, :-1] = toks[:, 1:]
        # pad the batch dim to a multiple of 64 to bound jit retraces without
        # over-scoring (pow2 padding cost up to +33% work on odd batch sizes)
        n = len(texts)
        nb = max(64, ((n + 63) // 64) * 64)
        if nb != n:
            toks = np.pad(toks, ((0, nb - n), (0, 0)))
            labels = np.pad(labels, ((0, nb - n), (0, 0)))
            mask = np.pad(mask, ((0, nb - n), (0, 0)))
            mask[n:, 0] = 1.0  # avoid 0/0 in padded rows
        losses = self._score_batch(
            self._params, jnp.asarray(toks), jnp.asarray(labels), jnp.asarray(mask)
        )
        losses = np.asarray(losses, np.float64)[:n]
        return np.exp(np.minimum(losses, 30.0))

    def process_batch(self, batch):
        self.setup()
        out = []
        # self-chunk at the accelerator-friendly batch size regardless of the
        # caller's batching (keeps the logits working set bounded)
        for i in range(0, len(batch), self.default_batch_size):
            chunk = batch[i : i + self.default_batch_size]
            ppls = self._ppl_batch([s.get("text", "") for s in chunk])
            for s, p in zip(chunk, ppls):
                s.setdefault("stats", {})[self.stat_key] = float(p)
                if self.min_val <= p <= self.max_val:
                    out.append(s)
        return out

    def _stat(self, s):  # pragma: no cover — batch path is used
        return float(self._ppl_batch([s.get("text", "")])[0])


@register("quality_score_filter")
class QualityScoreFilter(_RangeFilter):
    """Composite quality score from rule stats (logistic blend) — the
    rule-based counterpart of llm_quality_score_filter."""

    stat_key = "quality_score"
    text_only_stat = True  # _stat reads only sample["text"] -> columnar-safe

    def _stat(self, s):
        t = s.get("text", "")
        if not t:
            return 0.0
        words = t.split()
        n_words = len(words)
        alnum = sum(c.isalnum() or c.isspace() for c in t) / len(t)
        avg_wl = np.mean([len(w) for w in words]) if words else 0.0
        rep = 0.0
        if n_words >= 3:
            grams = [tuple(words[i : i + 3]) for i in range(n_words - 2)]
            rep = 1.0 - len(set(grams)) / len(grams)
        z = (
            1.5 * (alnum - 0.7) + 0.8 * math.tanh(n_words / 100.0)
            - 2.0 * rep + 0.3 * math.tanh((avg_wl - 2.0) / 4.0)
        )
        return float(1.0 / (1.0 + math.exp(-3.0 * z)))

    def _stat_values(self, block) -> np.ndarray:
        """Columnar path: the alnum term — the bulk of ``_stat``'s per-char
        work — comes off the buffer via the byte-class tables (exact on
        ASCII rows, per-char recompute otherwise); word splitting and the
        trigram-repetition term stay per row. Every term reproduces the row
        path bit-for-bit: integer counts divide identically, and the mean
        word length is an exact small-integer sum either way."""
        from repro.core.columnar import ascii_alnum_space_counts, ascii_rows_mask

        col = block.str_column("text")  # TypeError on non-str -> row fallback
        if col is None:
            return np.zeros(len(block), np.float64)
        offs, buf = col
        texts = block.string_values("text")
        ok = ascii_rows_mask(offs, buf).tolist()
        lens_b = (offs[1:] - offs[:-1]).tolist()
        acnt = ascii_alnum_space_counts(offs, buf).tolist()
        out = np.empty(len(texts), np.float64)
        for i, t in enumerate(texts):
            if not t:
                out[i] = 0.0
                continue
            words = t.split()
            n_words = len(words)
            alnum = (acnt[i] / lens_b[i] if ok[i]
                     else sum(c.isalnum() or c.isspace() for c in t) / len(t))
            # exact np.mean([len(w)...]) equivalent: an integer sum below
            # 2**53 divides identically
            avg_wl = float(sum(map(len, words))) / n_words if words else 0.0
            rep = 0.0
            if n_words >= 3:
                # same trigram tuples as the row path's slice loop
                rep = 1.0 - len(set(zip(words, words[1:], words[2:]))) \
                    / (n_words - 2)
            z = (
                1.5 * (alnum - 0.7) + 0.8 * math.tanh(n_words / 100.0)
                - 2.0 * rep + 0.3 * math.tanh((avg_wl - 2.0) / 4.0)
            )
            out[i] = 1.0 / (1.0 + math.exp(-3.0 * z))
        return out


@register("image_captioning_mapper")
class ImageCaptioningMapper(Mapper):
    """Synthesis: generates captions from image tags (offline stand-in for
    the BLIP-2 captioner; preserves token-aligned output schema)."""

    uses_model = True
    gpu_mem_required = 16 << 30

    def process_single(self, s):
        from repro.core import schema as S

        metas = s.get("image_meta", []) or []
        if not metas:
            return s
        caps = []
        for m in metas:
            tags = m.get("tags", [])
            caps.append(
                f"{S.IMAGE_TOKEN} a photo of " + (", ".join(tags) if tags else "something")
            )
        s = dict(s)
        s["text"] = (" " + S.EOC + " ").join(caps)
        return s
