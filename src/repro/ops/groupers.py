"""Grouper OPs: dataset -> groups (feeding Aggregators)."""
from __future__ import annotations

from repro.core.ops_base import Grouper
from repro.core.registry import register


@register("key_value_grouper")
class KeyValueGrouper(Grouper):
    """Groups samples by a meta (or stats) key's value. ``source`` picks the
    sample container the key is read from — ``"meta"`` (default, the
    historical behaviour) or ``"stats"`` (how SQL ``GROUP BY lang`` groups
    on a filter-computed stat column)."""

    def __init__(self, key: str = "domain", source: str = "meta", **kw):
        if source not in ("meta", "stats"):
            raise ValueError(f"source must be 'meta' or 'stats', got {source!r}")
        super().__init__(key=key, source=source, **kw)

    def group(self, samples):
        key, src = self.params["key"], self.params["source"]
        by: dict = {}
        for s in samples:
            by.setdefault((s.get(src) or {}).get(key, ""), []).append(s)
        return [by[k] for k in sorted(by, key=lambda v: (str(type(v)), v))]


@register("batch_grouper")
class BatchGrouper(Grouper):
    """Fixed-size groups in order."""

    def __init__(self, group_size: int = 8, **kw):
        super().__init__(group_size=group_size, **kw)

    def group(self, samples):
        g = self.params["group_size"]
        return [samples[i : i + g] for i in range(0, len(samples), g)]
