"""Grouper OPs: dataset -> groups (feeding Aggregators)."""
from __future__ import annotations

from repro.core.ops_base import Grouper
from repro.core.registry import register


@register("key_value_grouper")
class KeyValueGrouper(Grouper):
    """Groups samples by a meta key's value."""

    def __init__(self, key: str = "domain", **kw):
        super().__init__(key=key, **kw)

    def group(self, samples):
        by: dict = {}
        for s in samples:
            by.setdefault((s.get("meta") or {}).get(self.params["key"], ""), []).append(s)
        return [by[k] for k in sorted(by)]


@register("batch_grouper")
class BatchGrouper(Grouper):
    """Fixed-size groups in order."""

    def __init__(self, group_size: int = 8, **kw):
        super().__init__(group_size=group_size, **kw)

    def group(self, samples):
        g = self.params["group_size"]
        return [samples[i : i + g] for i in range(0, len(samples), g)]
