"""Aggregator OPs: combine a group of samples into one."""
from __future__ import annotations

from collections import Counter

from repro.core import schema as S
from repro.core.ops_base import Aggregator
from repro.core.registry import register


@register("concat_text_aggregator")
class ConcatTextAggregator(Aggregator):
    """Concatenates group texts with EOC separators (chunked document)."""

    def aggregate(self, group):
        text = S.EOC.join(s.get("text", "") for s in group)
        out = S.new_sample(text)
        out["meta"] = {"group_size": len(group)}
        return out


@register("keyword_summary_aggregator")
class KeywordSummaryAggregator(Aggregator):
    """Nested-aggregation stand-in: summarises a group by its most frequent
    content words (the paper's LLM summariser, offline rule variant)."""

    def __init__(self, top_k: int = 10, **kw):
        super().__init__(top_k=top_k, **kw)

    def aggregate(self, group):
        counts: Counter = Counter()
        for s in group:
            counts.update(w.lower() for w in s.get("text", "").split() if len(w) > 4)
        top = [w for w, _ in counts.most_common(self.params["top_k"])]
        out = S.new_sample("summary keywords: " + ", ".join(top))
        out["meta"] = {"group_size": len(group)}
        out["stats"] = {"n_keywords": float(len(top))}
        return out
