"""Post-tuning OPs (paper §3 / Fig. 3 families): extraction, calibration,
QA optimisation, preference-pair construction — offline rule-based
equivalents of the paper's LLM-backed operators, on the dialog schema
(query / response / history)."""
from __future__ import annotations

import re
from collections import Counter
from typing import List

from repro.core import schema as S
from repro.core.ops_base import Filter, Mapper
from repro.core.registry import register

_WS = re.compile(r"\s+")


@register("calibrate_query_mapper")
class CalibrateQueryMapper(Mapper):
    """Calibrates queries: trims noise, normalises spacing, ensures a
    question form (the paper's reference-text LLM calibration, rule form)."""

    def process_single(self, s):
        s = dict(s)
        q = _WS.sub(" ", s.get("query", "")).strip()
        if q and not q.endswith("?") and q.split()[0].lower() in (
            "what", "why", "how", "when", "where", "who", "which", "can", "does", "is"
        ):
            q += "?"
        s["query"] = q
        return s


@register("calibrate_response_mapper")
class CalibrateResponseMapper(Mapper):
    """Calibrates responses: strips boilerplate prefixes and dedups
    repeated sentences."""

    _PREFIXES = ("as an ai", "sure!", "sure,", "certainly!", "of course!")
    _SENT = re.compile(r"(?<=[.!?])\s+")

    def process_single(self, s):
        s = dict(s)
        r = _WS.sub(" ", s.get("response", "")).strip()
        low = r.lower()
        for p in self._PREFIXES:
            if low.startswith(p):
                r = r[len(p):].lstrip(" ,.!")
                break
        seen, out = set(), []
        for sent in self._SENT.split(r):
            key = sent.strip().lower()
            if key and key not in seen:
                seen.add(key)
                out.append(sent.strip())
        s["response"] = " ".join(out)
        return s


@register("extract_keyword_mapper")
class ExtractKeywordMapper(Mapper):
    """Generates keywords for the text into meta (paper's
    extract_keyword_mapper)."""

    def __init__(self, top_k: int = 8, **kw):
        super().__init__(top_k=top_k, **kw)

    def process_single(self, s):
        s = dict(s)
        words = [w.strip(".,!?;:").lower() for w in s.get("text", "").split()]
        counts = Counter(w for w in words if len(w) > 4)
        s["meta"] = dict(s.get("meta", {}),
                         keywords=[w for w, _ in counts.most_common(self.params["top_k"])])
        return s


@register("extract_entity_attribute_mapper")
class ExtractEntityAttributeMapper(Mapper):
    """Extracts 'X is Y' attribute pairs from text into meta (rule-based
    stand-in for the knowledge-graph extraction OPs)."""

    _PAT = re.compile(r"\b([A-Z][\w-]{2,})\s+(?:is|are|was|were)\s+([\w-]{3,})")

    def process_single(self, s):
        s = dict(s)
        pairs = self._PAT.findall(s.get("text", ""))[:16]
        s["meta"] = dict(s.get("meta", {}), entity_attributes=[list(p) for p in pairs])
        return s


@register("optimize_qa_mapper")
class OptimizeQAMapper(Mapper):
    """Optimises both query and response (composition of the calibrators)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self._q = CalibrateQueryMapper()
        self._r = CalibrateResponseMapper()

    def process_single(self, s):
        return self._r.process_single(self._q.process_single(s))


@register("pair_preference_mapper")
class PairPreferenceMapper(Mapper):
    """Constructs preference pairs: chosen = response, rejected = degraded
    variant (word-dropped), for DPO-style training data."""

    def __init__(self, degrade_rate: float = 0.25, seed: int = 0, **kw):
        super().__init__(degrade_rate=degrade_rate, seed=seed, **kw)

    def process_single(self, s):
        import numpy as np

        s = dict(s)
        r = s.get("response", "")
        words = r.split()
        rng = np.random.default_rng(self.params["seed"] + len(words))
        keep = rng.random(len(words)) >= self.params["degrade_rate"]
        s["meta"] = dict(s.get("meta", {}),
                         chosen=r, rejected=" ".join(w for w, k in zip(words, keep) if k))
        return s


@register("dialog_turns_filter")
class DialogTurnsFilter(Filter):
    """Keeps samples whose dialog turn count is within range."""

    def __init__(self, min_turns: int = 1, max_turns: int = 64, **kw):
        super().__init__(min_turns=min_turns, max_turns=max_turns, **kw)

    def compute_stats(self, sample):
        n = len(sample.get("history", []) or [])
        n += 1 if sample.get("query") else 0
        sample.setdefault("stats", {})["n_turns"] = float(n)
        return sample

    def keep(self, sample):
        return self.params["min_turns"] <= sample["stats"]["n_turns"] <= self.params["max_turns"]


@register("response_length_ratio_filter")
class ResponseLengthRatioFilter(Filter):
    """Keeps QA samples whose response/query length ratio is within range
    (degenerate one-word answers / runaway responses get dropped)."""

    def __init__(self, min_val: float = 0.2, max_val: float = 100.0, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)

    def compute_stats(self, sample):
        q = max(len(sample.get("query", "").split()), 1)
        r = len(sample.get("response", "").split())
        sample.setdefault("stats", {})["resp_len_ratio"] = r / q
        return sample

    def keep(self, sample):
        return self.params["min_val"] <= sample["stats"]["resp_len_ratio"] <= self.params["max_val"]


@register("llm_difficulty_score_filter")
class LLMDifficultyScoreFilter(Filter):
    """Difficulty proxy score (the paper notes rule-based methods struggle
    on e.g. math; this offline proxy blends rare-word rate, numeric density
    and query length — the LLM-scored variant plugs in via
    lm_perplexity_filter with a trained checkpoint)."""

    def __init__(self, min_val: float = 0.0, max_val: float = 1.0, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)

    def compute_stats(self, sample):
        import math

        text = (sample.get("query", "") + " " + sample.get("text", "")).strip()
        words = text.split()
        if not words:
            score = 0.0
        else:
            rare = sum(1 for w in words if len(w) > 8) / len(words)
            nums = sum(1 for w in words if any(c.isdigit() for c in w)) / len(words)
            score = 1.0 / (1.0 + math.exp(-(4 * rare + 3 * nums + 0.01 * len(words) - 1.5)))
        sample.setdefault("stats", {})["difficulty"] = float(score)
        return sample

    def keep(self, sample):
        return self.params["min_val"] <= sample["stats"]["difficulty"] <= self.params["max_val"]


@register("history_flatten_mapper")
class HistoryFlattenMapper(Mapper):
    """Flattens dialog history + current turn into pre-training text
    (schema conversion utility as an OP)."""

    def process_single(self, s):
        s = dict(s)
        msgs = S.to_query_response(s)
        s["text"] = "\n".join(f"{m['role']}: {m['content']}" for m in msgs)
        return s
