"""Multimodal OPs over the token-aligned schema.

Media payloads are represented by per-sample metadata/feature fields
(offline container: no real image/video files), but the OP semantics —
stats computation, alignment checks, cross-modal matching — follow the
paper's OPs of the same names.

Expected fields (produced by ``repro.data.synthetic``):
  images/videos/audios — path lists (aligned with text tokens)
  image_meta  — [{"width","height","bytes","nsfw_score","tags":[...]}]
  video_meta  — [{"duration","fps","frame_energy":[...] }]
  audio_meta  — [{"duration","rms_signal","rms_noise"}]
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from repro.core import schema as S
from repro.core.ops_base import Filter, Mapper
from repro.core.registry import register
from repro.ops.text_filters import _RangeFilter


@register("modality_alignment_filter")
class ModalityAlignmentFilter(Filter):
    """Keeps samples whose modality tokens align with their path lists
    (the schema validity check as an OP)."""

    def compute_stats(self, sample):
        ok, why = S.check_alignment(sample)
        sample.setdefault("stats", {})["aligned"] = 1.0 if ok else 0.0
        return sample

    def keep(self, sample):
        return sample["stats"]["aligned"] >= 1.0


@register("image_shape_filter")
class ImageShapeFilter(Filter):
    """Keeps samples whose images' width/height are within range."""

    io_intensive = True  # reads media metadata in the real system

    def __init__(self, min_width=32, max_width=1 << 16, min_height=32, max_height=1 << 16, any_or_all="any", **kw):
        super().__init__(min_width=min_width, max_width=max_width,
                         min_height=min_height, max_height=max_height,
                         any_or_all=any_or_all, **kw)

    def compute_stats(self, sample):
        metas = sample.get("image_meta", []) or []
        oks = [
            self.params["min_width"] <= m.get("width", 0) <= self.params["max_width"]
            and self.params["min_height"] <= m.get("height", 0) <= self.params["max_height"]
            for m in metas
        ]
        st = sample.setdefault("stats", {})
        st["image_widths"] = [m.get("width", 0) for m in metas]
        st["image_shape_ok"] = float(
            (any(oks) if self.params["any_or_all"] == "any" else all(oks)) if oks else 1.0
        )
        return sample

    def keep(self, sample):
        return sample["stats"]["image_shape_ok"] >= 1.0


@register("image_aspect_ratio_filter")
class ImageAspectRatioFilter(_RangeFilter):
    """Keeps samples whose images' aspect ratios are within range."""

    stat_key = "aspect_ratio_max"
    io_intensive = True

    def _stat(self, s):
        metas = s.get("image_meta", []) or []
        ratios = [m.get("width", 1) / max(m.get("height", 1), 1) for m in metas]
        return float(max(ratios)) if ratios else 1.0


@register("image_size_filter")
class ImageSizeFilter(_RangeFilter):
    """Keeps samples whose image byte sizes are within range."""

    stat_key = "image_bytes_max"
    io_intensive = True

    def _stat(self, s):
        metas = s.get("image_meta", []) or []
        return float(max((m.get("bytes", 0) for m in metas), default=0))


@register("image_nsfw_filter")
class ImageNSFWFilter(Filter):
    """Keeps samples whose max NSFW score is below threshold (privacy/safety
    family; model-based in the paper — scores precomputed here)."""

    uses_model = True
    gpu_mem_required = 1 << 30

    def __init__(self, threshold: float = 0.5, **kw):
        super().__init__(threshold=threshold, **kw)

    def compute_stats(self, sample):
        metas = sample.get("image_meta", []) or []
        sample.setdefault("stats", {})["nsfw_max"] = float(
            max((m.get("nsfw_score", 0.0) for m in metas), default=0.0)
        )
        return sample

    def keep(self, sample):
        return sample["stats"]["nsfw_max"] < self.params["threshold"]


@register("image_text_similarity_filter")
class ImageTextSimilarityFilter(_RangeFilter):
    """Keeps samples whose chunk text matches its image tags (bag-of-words
    cosine over hashed embeddings — the offline stand-in for CLIP)."""

    stat_key = "image_text_sim"
    uses_model = True
    gpu_mem_required = 2 << 30

    def _embed(self, words: List[str]) -> np.ndarray:
        import hashlib

        v = np.zeros(64, np.float32)
        for w in words:
            h = int.from_bytes(hashlib.blake2b(w.lower().encode(), digest_size=4).digest(), "little")
            v[h % 64] += 1.0
        n = np.linalg.norm(v)
        return v / n if n else v

    def _stat(self, s):
        metas = s.get("image_meta", []) or []
        if not metas:
            return 1.0
        text_emb = self._embed(s.get("text", "").split())
        sims = [float(text_emb @ self._embed(m.get("tags", []))) for m in metas]
        return float(np.mean(sims)) if sims else 1.0


@register("video_motion_score_filter")
class VideoMotionScoreFilter(_RangeFilter):
    """Keeps samples with video motion scores within range — the mean
    magnitude of inter-frame change (paper Fig. 5b; CPU/OpenCV variant
    adapted to per-frame energy series)."""

    stat_key = "motion_score"
    io_intensive = True

    def _stat(self, s):
        metas = s.get("video_meta", []) or []
        scores = []
        for m in metas:
            e = np.asarray(m.get("frame_energy", []), np.float32)
            scores.append(float(np.abs(np.diff(e)).mean()) if e.size > 1 else 0.0)
        return float(np.mean(scores)) if scores else 0.0


@register("video_duration_filter")
class VideoDurationFilter(_RangeFilter):
    """Keeps samples whose video durations are within range."""

    stat_key = "video_duration_max"

    def _stat(self, s):
        metas = s.get("video_meta", []) or []
        return float(max((m.get("duration", 0.0) for m in metas), default=0.0))


@register("audio_duration_filter")
class AudioDurationFilter(_RangeFilter):
    """Keeps samples whose audio durations are within range."""

    stat_key = "audio_duration_max"

    def _stat(self, s):
        metas = s.get("audio_meta", []) or []
        return float(max((m.get("duration", 0.0) for m in metas), default=0.0))


@register("audio_snr_filter")
class AudioSNRFilter(_RangeFilter):
    """Keeps samples whose audio SNR (dB) is within range (NMF-SNR analog)."""

    stat_key = "audio_snr_db"

    def _stat(self, s):
        metas = s.get("audio_meta", []) or []
        snrs = []
        for m in metas:
            sig, noise = m.get("rms_signal", 0.0), m.get("rms_noise", 1e-9)
            snrs.append(20.0 * math.log10(max(sig, 1e-9) / max(noise, 1e-9)))
        return float(min(snrs)) if snrs else 100.0


@register("image_face_blur_mapper")
class ImageFaceBlurMapper(Mapper):
    """Privacy mapper: marks faces blurred in image metadata (the real OP
    edits pixels; semantics preserved via metadata here)."""

    uses_model = True

    def process_single(self, s):
        s = dict(s)
        metas = [dict(m, faces_blurred=True) for m in s.get("image_meta", []) or []]
        s["image_meta"] = metas
        return s
