"""Text Filter OPs (cleaning). Each computes stats then filters by range —
the paper's Filter contract (compute_stats + keep)."""
from __future__ import annotations

import math
import re
import string
from typing import Optional, Tuple

import numpy as np

from repro.core.ops_base import Filter, shared_words
from repro.core.registry import register

_STOPWORDS = frozenset(
    "the a an and or but if then else of to in on for with at by from as is are was "
    "were be been being it its this that these those i you he she we they them his her".split()
)


class _RangeFilter(Filter):
    """Common stat-in-[min,max] retention."""

    stat_key = "stat"
    # columnar opt-in: True iff _stat reads ONLY sample["text"], so the
    # stat can be computed off the text column without row dicts. Subclasses
    # whose _stat touches other fields must leave this False.
    text_only_stat = False

    def __init__(self, min_val: float = -math.inf, max_val: float = math.inf, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)
        self.min_val, self.max_val = min_val, max_val

    def _stat(self, sample) -> float:
        raise NotImplementedError

    def compute_stats(self, sample):
        sample.setdefault("stats", {})[self.stat_key] = self._stat(sample)
        return sample

    def keep(self, sample):
        v = sample["stats"][self.stat_key]
        return self.min_val <= v <= self.max_val

    # -- columnar path -----------------------------------------------------
    def supports_columns(self):
        # only with the generic range keep(): a subclass overriding keep()
        # can't be reproduced by the min/max mask below
        return self.text_only_stat and type(self).keep is _RangeFilter.keep

    def _stat_values(self, block) -> np.ndarray:
        """Per-row stat values off the text column. Default: extract the
        strings (no row dicts) and reuse _stat; fully vectorized filters
        override this to stay on the buffers."""
        texts = block.string_values("text")
        out = np.empty(len(texts), np.float64)
        st = self._stat
        for i, t in enumerate(texts):
            out[i] = st({"text": t})
        return out

    def process_columns(self, block):
        vals = self._stat_values(block)
        mask = (vals >= self.min_val) & (vals <= self.max_val)
        # drop first, splice stats only into survivors — same bytes (the
        # row path's stat writes on dropped rows never reach an export)
        return block.take(mask).with_stat(self.stat_key, vals[mask])


@register("text_length_filter")
class TextLengthFilter(_RangeFilter):
    """Keeps samples whose text length (chars) is within range."""

    stat_key = "text_len"
    text_only_stat = True
    pushdown_safe = True  # fully vectorized: cheap enough for driver-side decode

    def _stat(self, s):
        return float(len(s.get("text", "")))

    def compute_stats_arrays(self, samples) -> Tuple[str, np.ndarray]:
        # vectorized path for the ShardedEngine
        return self.stat_key, np.asarray([len(s.get("text", "")) for s in samples], np.float32)

    def _stat_values(self, block) -> np.ndarray:
        # char counts straight off the UTF-8 buffer: a code point per
        # non-continuation byte — exact len(str), zero per-row work
        from repro.core.columnar import utf8_char_counts

        col = block.str_column("text")  # TypeError on non-str -> row fallback
        if col is None:
            return np.zeros(len(block), np.float64)
        return utf8_char_counts(*col).astype(np.float64)


@register("words_num_filter")
class WordsNumFilter(_RangeFilter):
    """Keeps samples with a word count within range."""

    stat_key = "num_words"
    text_only_stat = True

    def _stat(self, s):
        return float(len(shared_words(s)))

    def _stat_values(self, block) -> np.ndarray:
        # vectorized token count off the buffer; rows with non-ASCII bytes
        # (where byte != char classes) are recomputed exactly per row
        from repro.core.columnar import ascii_rows_mask, ascii_word_counts

        col = block.str_column("text")  # TypeError on non-str -> row fallback
        if col is None:
            return np.zeros(len(block), np.float64)
        offs, buf = col
        out = ascii_word_counts(offs, buf).astype(np.float64)
        bad = np.flatnonzero(~ascii_rows_mask(offs, buf))
        if bad.size:
            bounds = offs.tolist()
            for i in bad.tolist():
                out[i] = float(len(
                    buf[bounds[i]:bounds[i + 1]].decode("utf-8").split()))
        return out


@register("avg_word_length_filter")
class AvgWordLengthFilter(_RangeFilter):
    """Keeps samples whose mean word length is within range."""

    stat_key = "avg_word_len"
    text_only_stat = True

    def _stat(self, s):
        words = shared_words(s)
        return float(np.mean([len(w) for w in words])) if words else 0.0


@register("alnum_ratio_filter")
class AlnumRatioFilter(_RangeFilter):
    """Keeps samples with alphanumeric-character ratio within range."""

    stat_key = "alnum_ratio"
    text_only_stat = True

    def _stat(self, s):
        t = s.get("text", "")
        return sum(c.isalnum() or c.isspace() for c in t) / len(t) if t else 0.0

    def _stat_values(self, block) -> np.ndarray:
        # char-class counts off the buffer (chars == bytes on ASCII rows);
        # non-ASCII rows are recomputed exactly per row
        from repro.core.columnar import ascii_alnum_space_counts, ascii_rows_mask

        col = block.str_column("text")  # TypeError on non-str -> row fallback
        if col is None:
            return np.zeros(len(block), np.float64)
        offs, buf = col
        lens = (offs[1:] - offs[:-1]).astype(np.float64)
        cnt = ascii_alnum_space_counts(offs, buf).astype(np.float64)
        out = np.divide(cnt, lens, out=np.zeros_like(cnt), where=lens > 0)
        bad = np.flatnonzero(~ascii_rows_mask(offs, buf))
        if bad.size:
            bounds = offs.tolist()
            for i in bad.tolist():
                t = buf[bounds[i]:bounds[i + 1]].decode("utf-8")
                out[i] = (sum(c.isalnum() or c.isspace() for c in t) / len(t)
                          if t else 0.0)
        return out


@register("special_char_ratio_filter")
class SpecialCharRatioFilter(_RangeFilter):
    """Keeps samples whose special-character ratio is within range."""

    stat_key = "special_char_ratio"
    text_only_stat = True

    def _stat(self, s):
        t = s.get("text", "")
        if not t:
            return 1.0
        specials = sum(1 for c in t if (not c.isalnum()) and (not c.isspace())
                       and c not in ".,!?;:'\"()-")
        return specials / len(t)


@register("stopword_ratio_filter")
class StopwordRatioFilter(_RangeFilter):
    """Keeps samples whose stopword ratio is within range (low ratio often
    indicates non-natural-language content)."""

    stat_key = "stopword_ratio"
    text_only_stat = True

    def _stat(self, s):
        words = [w.strip(string.punctuation).lower() for w in shared_words(s)]
        return sum(w in _STOPWORDS for w in words) / len(words) if words else 0.0


@register("word_repetition_filter")
class WordRepetitionFilter(_RangeFilter):
    """Keeps samples whose top-ngram repetition fraction is within range."""

    stat_key = "word_rep_ratio"
    text_only_stat = True

    def __init__(self, n: int = 5, min_val: float = -math.inf,
                 max_val: float = math.inf, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)
        self.n = n
        self.params["n"] = n

    def _stat(self, s):
        words = shared_words(s)
        if len(words) < self.n:
            return 0.0
        grams = [tuple(words[i : i + self.n]) for i in range(len(words) - self.n + 1)]
        uniq = len(set(grams))
        return 1.0 - uniq / len(grams)


@register("char_repetition_filter")
class CharRepetitionFilter(_RangeFilter):
    """Keeps samples whose repeated-character-run fraction is within range."""

    stat_key = "char_rep_ratio"
    text_only_stat = True

    def _stat(self, s):
        t = s.get("text", "")
        if len(t) < 2:
            return 0.0
        runs = sum(1 for a, b in zip(t, t[1:]) if a == b)
        return runs / (len(t) - 1)


@register("language_heuristic_filter")
class LanguageHeuristicFilter(Filter):
    """Tags a coarse language family via script heuristics; keeps listed ones."""

    stats_keys = ("lang",)

    def __init__(self, keep_langs=("en",), **kw):
        super().__init__(keep_langs=tuple(keep_langs), **kw)
        self.keep_langs = set(keep_langs)

    def compute_stats(self, sample):
        t = sample.get("text", "")
        if not t:
            lang = "unknown"
        else:
            ascii_ratio = sum(ord(c) < 128 for c in t) / len(t)
            cjk = sum(0x4E00 <= ord(c) <= 0x9FFF for c in t) / len(t)
            if cjk > 0.2:
                lang = "zh"
            elif ascii_ratio > 0.9:
                lang = "en"
            else:
                lang = "other"
        sample.setdefault("stats", {})["lang"] = lang
        return sample

    def keep(self, sample):
        return sample["stats"]["lang"] in self.keep_langs


@register("token_count_filter")
class TokenCountFilter(_RangeFilter):
    """Keeps samples whose tokenized length is within range."""

    stat_key = "num_tokens"
    text_only_stat = True

    def __init__(self, min_val=0, max_val=math.inf, vocab_size: int = 32000, **kw):
        super().__init__(min_val=min_val, max_val=max_val, **kw)
        self.params["vocab_size"] = vocab_size
        self._tok = None
        self._vocab = vocab_size

    def setup(self):
        if self._tok is None:
            from repro.data.tokenizer import HashWordTokenizer

            self._tok = HashWordTokenizer(self._vocab)

    def _stat(self, s):
        self.setup()
        return float(len(self._tok.encode(s.get("text", ""))))


@register("maximum_line_length_filter")
class MaximumLineLengthFilter(_RangeFilter):
    """Keeps samples whose longest line is within range (code-ish heuristic)."""

    stat_key = "max_line_len"
    text_only_stat = True

    def _stat(self, s):
        lines = s.get("text", "").splitlines() or [""]
        return float(max(len(l) for l in lines))
