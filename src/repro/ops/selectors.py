"""Selector OPs: rank/rule-based dataset-level sampling."""
from __future__ import annotations

import numpy as np

from repro.core.ops_base import Selector
from repro.core.registry import register


@register("topk_stat_selector")
class TopKStatSelector(Selector):
    """Keeps the top-k (or top-fraction) samples by a stats key."""

    def __init__(self, stat_key: str, k: int = 0, fraction: float = 0.0,
                 descending: bool = True, **kw):
        super().__init__(stat_key=stat_key, k=k, fraction=fraction,
                         descending=descending, **kw)

    def select(self, samples):
        p = self.params
        vals = np.asarray(
            [s.get("stats", {}).get(p["stat_key"], -np.inf) for s in samples], np.float64
        )
        order = np.argsort(-vals if p["descending"] else vals, kind="stable")
        k = p["k"] or int(np.ceil(p["fraction"] * len(samples)))
        return [samples[int(i)] for i in order[: max(k, 0)]]


@register("random_selector")
class RandomSelector(Selector):
    """Seeded uniform subsample."""

    def __init__(self, k: int = 0, fraction: float = 0.0, seed: int = 0, **kw):
        super().__init__(k=k, fraction=fraction, seed=seed, **kw)

    def select(self, samples):
        p = self.params
        k = p["k"] or int(np.ceil(p["fraction"] * len(samples)))
        rng = np.random.default_rng(p["seed"])
        idx = rng.choice(len(samples), size=min(k, len(samples)), replace=False)
        return [samples[int(i)] for i in sorted(idx)]


@register("domain_diversity_selector")
class DomainDiversitySelector(Selector):
    """Greedy diversity selection: round-robin over a meta domain key so the
    kept subset covers domains evenly (paper's diversity selector family)."""

    def __init__(self, k: int, domain_key: str = "domain", **kw):
        super().__init__(k=k, domain_key=domain_key, **kw)

    def select(self, samples):
        p = self.params
        by_dom: dict = {}
        for s in samples:
            by_dom.setdefault((s.get("meta") or {}).get(p["domain_key"], ""), []).append(s)
        out = []
        doms = sorted(by_dom)
        i = 0
        while len(out) < p["k"] and any(by_dom[d] for d in doms):
            d = doms[i % len(doms)]
            if by_dom[d]:
                out.append(by_dom[d].pop(0))
            i += 1
        return out
