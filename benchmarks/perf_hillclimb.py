"""Reproduces the EXPERIMENTS.md §Perf hillclimb measurements.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--cell A|B|C|all]

Each row re-lowers + re-compiles the cell with the iteration's settings and
prints the three roofline terms. Takes several minutes per cell (512-device
SPMD compiles).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402


def _report(tag, res):
    if res.status != "ok":
        print(f"{tag}: {res.status} {res.note[:200]}")
        return
    rf = res.roofline
    print(f"{tag}: comp={rf['compute_s']:.2f}s mem={rf['memory_s']:.2f}s "
          f"coll={rf['collective_s']:.2f}s dom={rf['dominant']} "
          f"frac={rf['fraction']:.4f} mem/dev={res.memory['total_per_device']/2**30:.1f}GiB")


def cell_b():
    from repro.launch.dryrun import run_cell

    print("== Cell B: mixtral-8x22b x train_4k ==")
    _report("B0 einsum-dispatch baseline",
            run_cell("mixtral-8x22b", "train_4k", verbose=False, moe_dispatch="einsum"))
    _report("B1 scatter dispatch",
            run_cell("mixtral-8x22b", "train_4k", verbose=False))
    _report("B2 +bf16 params",
            run_cell("mixtral-8x22b", "train_4k", verbose=False, bf16_params=True))
    _report("B3 +micro=8",
            run_cell("mixtral-8x22b", "train_4k", verbose=False, bf16_params=True, microbatch=8))
    _report("B4 +expert-parallel mesh",
            run_cell("mixtral-8x22b", "train_4k", verbose=False, bf16_params=True,
                     microbatch=8, ep=8))
    _report("B5 +micro=4",
            run_cell("mixtral-8x22b", "train_4k", verbose=False, bf16_params=True,
                     microbatch=4, ep=8))


def cell_a():
    from repro.launch.dryrun import run_cell

    print("== Cell A: granite-moe-3b-a800m x prefill_32k ==")
    _report("A0 baseline", run_cell("granite-moe-3b-a800m", "prefill_32k", verbose=False))
    _report("A1 einsum dispatch on EP mesh (counterfactual)",
            run_cell("granite-moe-3b-a800m", "prefill_32k", verbose=False,
                     ep=8, moe_dispatch="einsum"))
    _report("A2 scatter + EP mesh",
            run_cell("granite-moe-3b-a800m", "prefill_32k", verbose=False, ep=8))


def cell_c():
    from repro.launch.dryrun import run_cell

    print("== Cell C: qwen1.5-110b x decode_32k ==")
    _report("C0-4 flash-decoding baseline",
            run_cell("qwen1.5-110b", "decode_32k", verbose=False))
    _report("C5 weight-stationary decode TP",
            run_cell("qwen1.5-110b", "decode_32k", verbose=False,
                     rule_overrides={"batch": (), "embed": ("data",)}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    args = ap.parse_args()
    if args.cell in ("B", "all"):
        cell_b()
    if args.cell in ("A", "all"):
        cell_a()
    if args.cell in ("C", "all"):
        cell_c()


if __name__ == "__main__":
    main()
