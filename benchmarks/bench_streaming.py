"""Streaming block-pipelined executor vs. barriered execution (paper §E.3).

The barriered path runs one dataset-wide pass per OP with full
materialization (and block re-splits) between OPs — on the parallel engine
that is a fresh process pool plus a full-dataset IPC round-trip PER OP. The
streaming path drives each block through a whole pipelineable segment in one
worker dispatch (one ``run_chain`` per block instead of n_ops x n_blocks
dataset-wide barriers), fed by a bounded prefetch queue and exported
block-by-block. The paper attributes 2-3x end-to-end wins to exactly this
(Fig. 4f); this bench asserts >=1.5x on the parallel engine plus identical
outputs plus lower peak traced memory, and reports the single-process
(structural-only) speedup as well.

NOTE: single-core container — the parallel-engine win measured here is
dispatch/IPC amortization, not multi-worker scaling.
"""
from __future__ import annotations

import json
import os
import tempfile
import tracemalloc

from benchmarks.common import emit, run_forked, timeit
from repro.core import obs
from repro.core.executor import Executor
from repro.core.recipes import Recipe
from repro.core.storage import iter_sample_blocks, write_jsonl
from repro.data.synthetic import make_corpus

PROCESS = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_val": 60},
    {"name": "alnum_ratio_filter", "min_val": 0.3},
    {"name": "words_num_filter", "min_val": 5},
    {"name": "quality_score_filter", "min_val": 0.05},
]

MIN_SPEEDUP = 1.5
MIN_BLOCKS = 8
REPEAT = 3


def _recipe(src: str, out: str, block_bytes: int, engine: str) -> Recipe:
    # optimizer off on BOTH sides: this bench isolates the execution
    # strategy (per-op barriers vs. block pipelining), not fusion
    return Recipe(name="bench_streaming", dataset_path=src, export_path=out,
                  process=list(PROCESS), block_bytes=block_bytes,
                  engine=engine, np=2, use_fusion=False, use_reordering=False)


def run(n: int = 4000, quick: bool = False):
    if quick:
        n = 1500
    corpus = make_corpus(n, seed=11, multimodal_frac=0.1)
    tmp = tempfile.mkdtemp(prefix="bench_streaming_")
    src = os.path.join(tmp, "in.jsonl")
    write_jsonl(src, corpus)
    del corpus  # forked children inherit parent pages — keep the baseline lean
    block_bytes = max(1, os.path.getsize(src) // (MIN_BLOCKS + 2))

    n_blocks = sum(1 for _ in iter_sample_blocks(src, block_bytes=block_bytes))
    assert n_blocks >= MIN_BLOCKS, f"corpus split into {n_blocks} blocks, want >={MIN_BLOCKS}"
    n_ops = len(PROCESS)
    assert n_ops >= 4

    # block-format phase FIRST: rss is measured on forked children, which
    # inherit every resident parent page — running the other phases first
    # would leave ~tens of MB of recycled heap in the parent whose pages
    # absorb the children's allocations and erase the row/columnar margin.
    # The chain is the filter-leading shape the optimizer's reordering
    # produces in practice — the columnar prefix + predicate pushdown engage
    # there (a mapper-led chain degenerates to the row shim for both formats
    # and measures nothing). Forked children give isolated peak-RSS (worker
    # processes included via wait4 rusage); exports must match byte-for-byte
    # — the format is an execution detail, never a semantics change.
    fmt_process = [c for c in PROCESS
                   if c["name"] != "whitespace_normalization_mapper"]
    fmt_process.append({"name": "whitespace_normalization_mapper"})
    out_r = os.path.join(tmp, "out_fmt_row.jsonl")
    out_c = os.path.join(tmp, "out_fmt_col.jsonl")

    # larger corpus for this phase: the memory story is data-dominated — at
    # the streaming phase's size the dict-vs-buffer difference drowns under
    # the interpreter baseline (~tens of MB per process)
    n_fmt = n * 4
    src_fmt = os.path.join(tmp, "in_fmt.jsonl")
    write_jsonl(src_fmt, make_corpus(n_fmt, seed=11, multimodal_frac=0.1))
    bb_fmt = max(1, os.path.getsize(src_fmt) // (MIN_BLOCKS + 2))

    def run_fmt(fmt: str, out: str) -> None:
        r = _recipe(src_fmt, out, bb_fmt, "parallel")
        r.process = list(fmt_process)
        r.block_format = fmt
        Executor(r).run_streaming(materialize=False)

    rep_fmt = 1 if quick else REPEAT
    t_row, rss_row = run_forked(lambda: run_fmt("row", out_r), repeat=rep_fmt)
    t_col, rss_col = run_forked(lambda: run_fmt("columnar", out_c), repeat=rep_fmt)
    with open(out_r, "rb") as f:
        bytes_row = f.read()
    with open(out_c, "rb") as f:
        bytes_col = f.read()
    assert bytes_col == bytes_row, "columnar export must be byte-identical to row"
    emit("block_format_row_parallel", t_row,
         f"n={n_fmt} peak_rss_mb={rss_row / 2**20:.1f}")
    emit("block_format_columnar_parallel", t_col,
         f"peak_rss_mb={rss_col / 2**20:.1f} "
         f"{t_row / max(t_col, 1e-9):.2f}x vs row, "
         f"rss {rss_row / max(rss_col, 1):.2f}x lower")
    if not quick:  # quick runs are too short/small for stable margins
        assert t_col < t_row, (
            f"columnar {t_col:.3f}s not faster than row {t_row:.3f}s")
        assert rss_col <= rss_row, (
            f"columnar peak RSS {rss_col} above row path {rss_row}")

    out_s = os.path.join(tmp, "out_streaming.jsonl")
    out_b = os.path.join(tmp, "out_barriered.jsonl")
    results = {}
    for engine in ("local", "parallel"):
        ex = Executor(_recipe(src, out_s, block_bytes, engine))
        assert ex.streaming_eligible(), "run() must auto-select streaming here"
        t_s = timeit(lambda: ex.run(), repeat=REPEAT)
        _, rep_s = Executor(_recipe(src, out_s, block_bytes, engine)).run()
        assert rep_s.streaming

        t_b = timeit(
            lambda: Executor(_recipe(src, out_b, block_bytes, engine)).run_barriered(),
            repeat=REPEAT)
        _, rep_b = Executor(_recipe(src, out_b, block_bytes, engine)).run_barriered()

        with open(out_s, "rb") as f:
            bytes_s = f.read()
        with open(out_b, "rb") as f:
            bytes_b = f.read()
        assert bytes_s == bytes_b, "streaming output must be identical to barriered"
        assert rep_s.n_out == rep_b.n_out
        results[engine] = t_b / t_s
        emit(f"streaming_{engine}", t_s, f"n={n} ops={n_ops} blocks={n_blocks}")
        emit(f"barriered_{engine}", t_b, f"{results[engine]:.2f}x slower than streaming")

    # peak memory (tracemalloc; separate phase so timing stays undistorted;
    # local engine only — tracemalloc cannot see worker processes).
    # streaming exports block-by-block with materialize=False — the
    # "stream to disk, never materialize" configuration.
    tracemalloc.start()
    Executor(_recipe(src, out_s, block_bytes, "local")).run_streaming(materialize=False)
    _, peak_s = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    tracemalloc.start()
    Executor(_recipe(src, out_b, block_bytes, "local")).run_barriered()
    _, peak_b = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    emit("streaming_speedup", 0.0,
         f"parallel {results['parallel']:.2f}x / local {results['local']:.2f}x "
         f"(target >={MIN_SPEEDUP}x), peak mem {peak_s / 2**20:.1f}MB vs "
         f"{peak_b / 2**20:.1f}MB ({peak_b / max(peak_s, 1):.2f}x lower)")
    assert results["parallel"] >= MIN_SPEEDUP, (
        f"streaming speedup {results['parallel']:.2f}x < {MIN_SPEEDUP}x")
    if not quick:  # quick-mode corpora are too small for a stable mem margin
        assert peak_s < peak_b, "streaming peak memory must be lower"

    # tracing overhead: same streaming run with obs off vs. on. Spans are
    # bounded dicts + one lock per block, so the budget is <=5% (paper-style
    # always-on observability only earns its keep if it is ~free). The small
    # absolute floor absorbs scheduler noise on sub-second quick runs.
    obs.disable()
    try:
        t_off = timeit(
            lambda: Executor(_recipe(src, out_s, block_bytes, "local")).run(),
            repeat=REPEAT)
    finally:
        obs.enable()
    t_on = timeit(
        lambda: Executor(_recipe(src, out_s, block_bytes, "local")).run(),
        repeat=REPEAT)
    _, rep_tr = Executor(_recipe(src, out_s, block_bytes, "parallel")).run()
    trace = rep_tr.trace or {}
    spans = trace.get("spans") or []
    assert spans, "traced run must surface spans on RunReport.trace"
    trace_path = os.path.join(os.getcwd(), "TRACE_streaming.json")
    with open(trace_path, "w", encoding="utf-8") as f:
        json.dump(obs.chrome_trace(spans), f)
    overhead = t_on / max(t_off, 1e-9)
    emit("tracing_overhead", t_on - t_off,
         f"off={t_off:.3f}s on={t_on:.3f}s {overhead:.3f}x "
         f"(budget <=1.05x), {len(spans)} spans -> {trace_path}")
    assert t_on <= t_off * 1.05 + 0.05, (
        f"tracing overhead {overhead:.3f}x blows the 5% budget "
        f"(on={t_on:.3f}s off={t_off:.3f}s)")
    return results


if __name__ == "__main__":
    import sys

    from benchmarks.common import dump_json, parse_bench_args

    quick, json_path = parse_bench_args(sys.argv[1:])
    run(quick=quick)
    if json_path:
        dump_json(json_path)
