"""Shared benchmark helpers: timing + CSV rows (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timeit(fn: Callable, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def dump_json(path: str) -> None:
    """Write every emitted row to ``path`` as JSON — the CI bench-smoke
    artifact format (one object per row: name, us_per_call, derived)."""
    import json

    with open(path, "w") as f:
        json.dump([{"name": n, "us_per_call": u, "derived": d}
                   for n, u, d in ROWS], f, indent=2)
    print(f"[bench] wrote {len(ROWS)} rows to {path}")


def parse_bench_args(argv: List[str]) -> Tuple[bool, str]:
    """Shared benchmark CLI: returns (quick, json_path). Accepts
    ``--quick`` and ``--json PATH``."""
    quick = "--quick" in argv
    json_path = ""
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json requires a PATH argument")
        json_path = argv[i + 1]
    return quick, json_path
