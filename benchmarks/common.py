"""Shared benchmark helpers: timing + CSV rows (name,us_per_call,derived)."""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple


def timeit(fn: Callable, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_forked(fn: Callable, repeat: int = 1) -> Tuple[float, int]:
    """Run ``fn()`` in a forked child per repeat; returns (best seconds,
    max peak-RSS bytes) measured via ``os.wait4``'s rusage. Forking isolates
    the measurement: the parent's allocator high-water mark (earlier bench
    phases, corpora) never pollutes the child's ru_maxrss, and worker
    subprocesses ARE included (RUSAGE_CHILDREN folds into the wait4 child).
    Falls back to in-process timing + RUSAGE_SELF where fork is missing."""
    import resource

    if not hasattr(os, "fork"):  # pragma: no cover - non-POSIX fallback
        best = timeit(fn, repeat=repeat)
        return best, resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    best, rss = float("inf"), 0
    for _ in range(repeat):
        t0 = time.perf_counter()
        pid = os.fork()
        if pid == 0:  # child
            code = 0
            try:
                fn()
            except BaseException:  # noqa: BLE001 — report, then hard-exit
                import traceback

                traceback.print_exc()
                code = 1
            finally:
                os._exit(code)
        _, status, ru = os.wait4(pid, 0)
        dt = time.perf_counter() - t0
        if not (os.WIFEXITED(status) and os.WEXITSTATUS(status) == 0):
            raise RuntimeError(f"forked bench child failed (status={status})")
        best = min(best, dt)
        rss = max(rss, ru.ru_maxrss * 1024)  # linux: ru_maxrss is KiB
    return best, rss


ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def dump_json(path: str) -> None:
    """Write every emitted row to ``path`` as JSON — the CI bench-smoke
    artifact format (one object per row: name, us_per_call, derived)."""
    import json

    with open(path, "w") as f:
        json.dump([{"name": n, "us_per_call": u, "derived": d}
                   for n, u, d in ROWS], f, indent=2)
    print(f"[bench] wrote {len(ROWS)} rows to {path}")


def parse_bench_args(argv: List[str]) -> Tuple[bool, str]:
    """Shared benchmark CLI: returns (quick, json_path). Accepts
    ``--quick`` and ``--json PATH``."""
    quick = "--quick" in argv
    json_path = ""
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json requires a PATH argument")
        json_path = argv[i + 1]
    return quick, json_path
