"""Shared benchmark helpers: timing + CSV rows (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timeit(fn: Callable, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}")
