"""Paper Table 2: MinHash dedup time vs dataset size (+ §E.1's 3.3x
balanced-vs-vanilla comparison).

Validated ratios (scaled to this container):
  * 5x data  -> 4.02-5.62x time in the paper; we report time(5x)/time(1x).
  * balanced union-find + hash aggregation vs naive chaining.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.dedup.minhash import minhash_dedup_indices
from repro.data.synthetic import make_corpus


def run(base_n: int = 600, scales=(1, 5), n_perm: int = 128):
    texts_by_scale = {}
    for s in scales:
        corpus = make_corpus(base_n * s, seed=11, dup_frac=0.25, near_dup_frac=0.15,
                             multimodal_frac=0.0)
        texts_by_scale[s] = [x["text"] for x in corpus]

    times = {}
    for s in scales:
        t = timeit(lambda s=s: minhash_dedup_indices(
            texts_by_scale[s], n_perm=n_perm, backend="balanced"))
        times[s] = t
        emit(f"dedup_balanced_x{s}", t, f"n={base_n * s}")
    if len(scales) >= 2:
        a, b = scales[0], scales[-1]
        ratio = times[b] / times[a]
        emit("dedup_data_scaling", times[b],
             f"{b}x data -> {ratio:.2f}x time (paper: 4.02-5.62x)")

    # balanced vs naive backend on the largest scale
    s = scales[-1]
    t_naive = timeit(lambda: minhash_dedup_indices(
        texts_by_scale[s], n_perm=n_perm, backend="naive"))
    emit("dedup_naive", t_naive, f"n={base_n * s}")
    emit("dedup_balanced_speedup", times[s],
         f"naive/balanced = {t_naive / times[s]:.2f}x (paper's engine-level: 3.3x)")

    # load-balanced vs naive union-find at the ALGORITHMIC level: long
    # duplicate chains are the adversarial case (naive chaining degrades to
    # O(n^2) finds; union-by-rank + path-halving stays near-linear) — the
    # structure behind the paper's engine-level 3.3x.
    from repro.core.dedup.unionfind import BalancedUnionFind, naive_components

    n_chain = 30000
    # reversed chain: worst case for unbalanced chaining (find degrades to
    # O(n) -> O(n^2) total), benign for union-by-rank + path-halving
    chain_edges = [(i, i + 1) for i in range(n_chain - 2, -1, -1)]
    t_bal = timeit(lambda: BalancedUnionFind(n_chain).add_edges(chain_edges))
    t_nv = timeit(lambda: naive_components(n_chain, chain_edges))
    emit("uf_chain_balanced", t_bal, f"{n_chain}-node chain")
    emit("uf_chain_naive", t_nv,
         f"naive/balanced = {t_nv / t_bal:.1f}x (load-balanced UF claim)")

    # kernel-path signatures (Pallas interpret) vs host signatures
    from repro.core.dedup.minhash import shingle_hashes, signatures_batch

    docs = [shingle_hashes(t) for t in texts_by_scale[scales[0]][:200]]
    t_host = timeit(lambda: signatures_batch(docs, n_perm=n_perm))
    t_kernel = timeit(lambda: signatures_batch(docs, n_perm=n_perm, use_kernel=True))
    emit("minhash_sig_host", t_host, "numpy M61 path")
    emit("minhash_sig_pallas_interpret", t_kernel,
         "TPU kernel (interpret mode; compiled-TPU timing N/A on CPU)")


if __name__ == "__main__":
    run()
