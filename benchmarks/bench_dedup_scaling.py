"""Paper Table 2: MinHash dedup time vs dataset size (+ §E.1's 3.3x
balanced-vs-vanilla comparison), plus the streaming-vs-barriered dedup
comparison (``run_streaming_mode``): a map -> filter -> dedup -> filter
recipe executed (a) fully barriered, (b) streaming with dedup as a barrier
segment, (c) streaming with the incremental keep-first stage, (d) streaming
with the exact two-pass stage — wall-clock, peak traced memory, byte-level
output checks.

Validated ratios (scaled to this container):
  * 5x data  -> 4.02-5.62x time in the paper; we report time(5x)/time(1x).
  * balanced union-find + hash aggregation vs naive chaining.
  * streaming keep-first >= 1.5x over the barriered run, flat memory.
"""
from __future__ import annotations

import os
import tempfile
import tracemalloc

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.dedup.minhash import minhash_dedup_indices
from repro.data.synthetic import make_corpus


def run(base_n: int = 600, scales=(1, 5), n_perm: int = 128):
    texts_by_scale = {}
    for s in scales:
        corpus = make_corpus(base_n * s, seed=11, dup_frac=0.25, near_dup_frac=0.15,
                             multimodal_frac=0.0)
        texts_by_scale[s] = [x["text"] for x in corpus]

    times = {}
    for s in scales:
        t = timeit(lambda s=s: minhash_dedup_indices(
            texts_by_scale[s], n_perm=n_perm, backend="balanced"))
        times[s] = t
        emit(f"dedup_balanced_x{s}", t, f"n={base_n * s}")
    if len(scales) >= 2:
        a, b = scales[0], scales[-1]
        ratio = times[b] / times[a]
        emit("dedup_data_scaling", times[b],
             f"{b}x data -> {ratio:.2f}x time (paper: 4.02-5.62x)")

    # balanced vs naive backend on the largest scale
    s = scales[-1]
    t_naive = timeit(lambda: minhash_dedup_indices(
        texts_by_scale[s], n_perm=n_perm, backend="naive"))
    emit("dedup_naive", t_naive, f"n={base_n * s}")
    emit("dedup_balanced_speedup", times[s],
         f"naive/balanced = {t_naive / times[s]:.2f}x (paper's engine-level: 3.3x)")

    # load-balanced vs naive union-find at the ALGORITHMIC level: long
    # duplicate chains are the adversarial case (naive chaining degrades to
    # O(n^2) finds; union-by-rank + path-halving stays near-linear) — the
    # structure behind the paper's engine-level 3.3x.
    from repro.core.dedup.unionfind import BalancedUnionFind, naive_components

    n_chain = 30000
    # reversed chain: worst case for unbalanced chaining (find degrades to
    # O(n) -> O(n^2) total), benign for union-by-rank + path-halving
    chain_edges = [(i, i + 1) for i in range(n_chain - 2, -1, -1)]
    t_bal = timeit(lambda: BalancedUnionFind(n_chain).add_edges(chain_edges))
    t_nv = timeit(lambda: naive_components(n_chain, chain_edges))
    emit("uf_chain_balanced", t_bal, f"{n_chain}-node chain")
    emit("uf_chain_naive", t_nv,
         f"naive/balanced = {t_nv / t_bal:.1f}x (load-balanced UF claim)")

    # kernel-path signatures (Pallas interpret) vs host signatures
    from repro.core.dedup.minhash import shingle_hashes, signatures_batch

    docs = [shingle_hashes(t) for t in texts_by_scale[scales[0]][:200]]
    t_host = timeit(lambda: signatures_batch(docs, n_perm=n_perm))
    t_kernel = timeit(lambda: signatures_batch(docs, n_perm=n_perm, use_kernel=True))
    emit("minhash_sig_host", t_host, "numpy M61 path")
    emit("minhash_sig_pallas_interpret", t_kernel,
         "TPU kernel (interpret mode; compiled-TPU timing N/A on CPU)")


# ---------------------------------------------------------------------------
# streaming dedup vs. the barriered run (ISSUE 3 acceptance benchmark)
# ---------------------------------------------------------------------------

MIN_STREAM_SPEEDUP = 1.5   # the paper's structural (multi-core) target
# enforcement floor: on this single-core container the streaming win is
# dispatch/IPC amortization only and the measured ratio swings 1.2-1.9x
# with system load phase (observed across identical trees) — assert a
# margin that catches structural regressions without coin-flip failures
MIN_STREAM_FLOOR = 1.15
MIN_BLOCKS = 8
_DEDUP = "document_minhash_deduplicator"


def _dedup_recipe(src: str, out: str, mode: str, block_bytes: int,
                  engine: str = "parallel"):
    from repro.core.recipes import Recipe

    return Recipe(
        name=f"bench_dedup_{mode}", dataset_path=src, export_path=out,
        process=[
            {"name": "clean_links_mapper"},
            {"name": "whitespace_normalization_mapper"},
            {"name": "text_length_filter", "min_val": 30},
            {"name": "words_num_filter", "min_val": 5},
            {"name": _DEDUP, "jaccard_threshold": 0.6, "streaming": mode,
             "super_batch": 512},
            {"name": "alnum_ratio_filter", "min_val": 0.5},
            {"name": "quality_score_filter", "min_val": 0.05},
        ],
        block_bytes=block_bytes, engine=engine, np=2,
        use_fusion=False, use_reordering=False)


def run_streaming_mode(n: int = 3000, quick: bool = False):
    """map -> filter -> dedup -> filter, end-to-end through Executor.run:
    wall-clock per mode, output equivalence, and peak traced memory for the
    stream-to-disk configuration (keep-first holds O(band index), the
    barriered run holds the whole dataset)."""
    from repro.core.executor import Executor
    from repro.core.storage import read_jsonl, write_jsonl

    if quick:
        n = 800
    corpus = make_corpus(n, seed=11, dup_frac=0.3, near_dup_frac=0.15,
                         multimodal_frac=0.0)
    tmp = tempfile.mkdtemp(prefix="bench_dedup_stream_")
    src = os.path.join(tmp, "in.jsonl")
    write_jsonl(src, corpus)
    block_bytes = max(1, os.path.getsize(src) // (MIN_BLOCKS + 2))
    # best-of-3: the target margin is ~1.5x and single-core scheduling noise
    # is +-0.2s per run — two repeats leave the assert a coin flip
    repeat = 1 if quick else 3

    out = {m: os.path.join(tmp, f"out_{m}.jsonl")
           for m in ("barriered", "off", "keep_first", "exact")}
    for mode in ("off", "keep_first", "exact"):
        ex = Executor(_dedup_recipe(src, out[mode], mode, block_bytes))
        assert ex.streaming_eligible()
        _, rep = ex.run()  # also warms pools/imports before timing
        assert rep.streaming

    # interleaved rounds (barriered + every mode per round, best-of): this
    # box's throughput drifts over minutes, so timing each mode in its own
    # sequential pass lets a slow phase land on one side of the ratio
    t_bar = float("inf")
    times = {m: float("inf") for m in ("off", "keep_first", "exact")}
    for _ in range(repeat):
        t_bar = min(t_bar, timeit(lambda: Executor(_dedup_recipe(
            src, out["barriered"], "off", block_bytes)).run_barriered()))
        for mode in times:
            times[mode] = min(times[mode], timeit(
                lambda mode=mode: Executor(_dedup_recipe(
                    src, out[mode], mode, block_bytes)).run()))
    emit("dedup_e2e_barriered", t_bar, f"n={n} full per-op materialization")
    for mode in ("off", "keep_first", "exact"):
        emit(f"dedup_e2e_stream_{mode}", times[mode],
             f"{t_bar / times[mode]:.2f}x vs barriered")

    # output contracts: exact (and the barrier segment) reproduce the
    # barriered bytes; keep-first keeps a superset of the exact keep set
    with open(out["barriered"], "rb") as f:
        ref = f.read()
    with open(out["exact"], "rb") as f:
        assert f.read() == ref, "exact streaming must be byte-identical"
    with open(out["off"], "rb") as f:
        assert f.read() == ref, "barrier-segment streaming must match"
    kept_exact = {s["text"] for s in read_jsonl(out["exact"])}
    kept_kf = {s["text"] for s in read_jsonl(out["keep_first"])}
    assert kept_exact <= kept_kf, "keep-first must keep a superset"

    # peak traced memory, stream-to-disk configuration (local engine —
    # tracemalloc cannot see worker processes)
    tracemalloc.start()
    Executor(_dedup_recipe(src, out["keep_first"], "keep_first", block_bytes,
                           engine="local")).run_streaming(materialize=False)
    _, peak_s = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    tracemalloc.start()
    Executor(_dedup_recipe(src, out["barriered"], "off", block_bytes,
                           engine="local")).run_barriered()
    _, peak_b = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    try:
        import resource

        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    except Exception:  # noqa: BLE001 — resource is POSIX-only
        rss = 0
    speedup = t_bar / times["keep_first"]
    emit("dedup_stream_speedup", times["keep_first"],
         f"keep_first {speedup:.2f}x vs barriered (target >={MIN_STREAM_SPEEDUP}x), "
         f"peak mem {peak_s / 2**20:.1f}MB vs {peak_b / 2**20:.1f}MB "
         f"({peak_b / max(peak_s, 1):.2f}x lower), process ru_maxrss {rss}KB")
    if not quick:  # quick corpora are too small for stable wall-clock margins
        assert speedup >= MIN_STREAM_FLOOR, (
            f"streaming dedup speedup {speedup:.2f}x < floor {MIN_STREAM_FLOOR}x")
        assert peak_s < peak_b, "streaming dedup peak memory must be lower"
    return speedup


def run_block_format(n: int = 12000, quick: bool = False):
    """Row dicts vs ColumnBlocks through the full dedup chain (filters ->
    streaming keep-first dedup -> mapper) on the parallel engine. Columnar
    blocks keep the filter prefix on buffers and hand the dedup stage
    presigned carriers it reads without decoding rows. Forked children give
    isolated peak-RSS (parent pages are inherited, so this phase must run
    before anything else bloats the parent); exports must be byte-identical
    — the block format is an execution detail."""
    from benchmarks.common import run_forked
    from repro.core.executor import Executor
    from repro.core.storage import write_jsonl

    if quick:
        n = 2000
    tmp = tempfile.mkdtemp(prefix="bench_dedup_fmt_")
    src = os.path.join(tmp, "in.jsonl")
    write_jsonl(src, make_corpus(n, seed=11, dup_frac=0.3, near_dup_frac=0.15,
                                 multimodal_frac=0.0))
    block_bytes = max(1, os.path.getsize(src) // (MIN_BLOCKS + 2))

    # filter-leading shape (what reordering produces) so the columnar prefix
    # engages; keep-first dedup is order-deterministic -> same bytes
    process = [
        {"name": "text_length_filter", "min_val": 30},
        {"name": "words_num_filter", "min_val": 5},
        {"name": "alnum_ratio_filter", "min_val": 0.5},
        {"name": "quality_score_filter", "min_val": 0.05},
        {"name": _DEDUP, "jaccard_threshold": 0.6, "streaming": "keep_first",
         "super_batch": 512},
        {"name": "whitespace_normalization_mapper"},
    ]

    def run_fmt(fmt: str, out: str) -> None:
        r = _dedup_recipe(src, out, "keep_first", block_bytes)
        r.process = [dict(c) for c in process]
        r.block_format = fmt
        Executor(r).run_streaming(materialize=False)

    out_r = os.path.join(tmp, "out_fmt_row.jsonl")
    out_c = os.path.join(tmp, "out_fmt_col.jsonl")
    rep = 1 if quick else 2
    t_row, rss_row = run_forked(lambda: run_fmt("row", out_r), repeat=rep)
    t_col, rss_col = run_forked(lambda: run_fmt("columnar", out_c), repeat=rep)
    with open(out_r, "rb") as f:
        bytes_row = f.read()
    with open(out_c, "rb") as f:
        bytes_col = f.read()
    assert bytes_col == bytes_row, "columnar export must be byte-identical to row"
    emit("dedup_block_format_row", t_row, f"n={n} peak_rss_mb={rss_row / 2**20:.1f}")
    emit("dedup_block_format_columnar", t_col,
         f"peak_rss_mb={rss_col / 2**20:.1f} "
         f"{t_row / max(t_col, 1e-9):.2f}x vs row, "
         f"rss {rss_row / max(rss_col, 1):.2f}x lower")
    return t_row / max(t_col, 1e-9)


if __name__ == "__main__":
    import sys

    from benchmarks.common import dump_json, parse_bench_args

    quick, json_path = parse_bench_args(sys.argv[1:])
    run_block_format(quick=quick)  # first: forked-RSS phase needs a lean parent
    run(base_n=150 if quick else 600)
    run_streaming_mode(quick=quick)
    if json_path:
        dump_json(json_path)
