"""Cluster throughput + failover benchmark (paper §5.2 scale claim, scaled
down to CI size).

Measures multi-job throughput of the distributed job queue at 1 / 2 / 4
REAL runner subprocesses sharing one ``cluster_dir``, and the kill-mid-job
recovery path (SIGKILL the lease holder, time lease-expiry -> re-claim ->
checkpoint-resume -> completion).

Hard asserts (correctness, never flake-prone wall-clock alone):
  * every submitted job succeeds at every runner count;
  * the killed job completes on the surviving runner at attempt 2 with a
    checkpoint resume, byte-identical to an uninterrupted run;
  * 2-runner throughput >= 1.7x 1-runner throughput on the multi-job
    workload (jobs are sleep-paced, so the ratio measures scheduling, not
    the host's core count).

With ``--sharded``, adds the intra-job scale-out phase: ONE sharded
streaming-dedup job (repro.api.shards) at 1 / 2 / 4 runners, asserting
  * the merged export is byte-identical to the unsharded single-runner
    run at every runner count;
  * 2 runners finish the single job >= 1.6x faster than 1 (the shard
    maps are sleep-paced, so the ratio measures shard placement).

With ``--multi-tenant``, adds the noisy-neighbor isolation phase: one
heavy tenant floods the queue with sleep-paced jobs, then a light tenant
submits a few; both phases (pure-FIFO claiming vs weighted deficit
round-robin, toggled per-runner via the ``DJ_FAIR_SHARE`` env) run on one
single-capacity runner, asserting
  * the light tenant's p95 queue-wait under fair-share is >=2x better
    than under FIFO;
  * every job succeeds and the light tenant's exports are byte-identical
    across both scheduling modes.

Usage: python benchmarks/bench_cluster.py [--quick] [--sharded]
       [--multi-tenant] [--json PATH]
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(__file__))                    # common
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "tests"))                 # harness
sys.path.insert(0, os.path.join(_ROOT, "src"))

from common import dump_json, emit, parse_bench_args  # noqa: E402
from cluster_harness import (  # noqa: E402
    checkpoint_stages, lease_owner, make_recipe, make_sharded_recipe,
    reference_output, sigkill_runner, start_runner, stop_runner, wait_for,
    write_corpus,
)
from repro.api.cluster import ClusterQueue  # noqa: E402

LEASE_TTL = 2.0
DEFER = 0.05  # greedy claims: throughput runs measure scheduling, not politeness


def _job_recipe(src: str, out: str, delay: float) -> dict:
    return {
        "name": "bench-cluster-job",
        "dataset_path": src,
        "export_path": out,
        "process": [
            {"name": "whitespace_normalization_mapper"},
            {"name": "sleep_mapper", "delay": delay},
            {"name": "text_length_filter", "min_val": 20},
        ],
        "use_fusion": False,
        "use_reordering": False,
    }


def _start_runners(cdir: str, n: int):
    runners = [start_runner(cdir, f"bench-runner-{i}", lease_ttl=LEASE_TTL,
                            poll=0.05, defer=DEFER) for i in range(n)]
    q = ClusterQueue(cdir)
    wait_for(lambda: len(q.runner_cards()) >= n, 60,
             message=f"{n} runner cards live")
    return runners


def run_throughput(n_runners: int, n_jobs: int, delay: float,
                   n_samples: int) -> float:
    """Jobs/sec with ``n_runners`` subprocesses draining ``n_jobs`` equal
    sleep-paced jobs. Runners are started and idle BEFORE the clock starts —
    interpreter startup is deployment cost, not queue throughput."""
    base = tempfile.mkdtemp(prefix=f"djc{n_runners}_")
    try:
        src = write_corpus(os.path.join(base, "corpus.jsonl"), n=n_samples)
        cdir = os.path.join(base, "cluster")
        q = ClusterQueue(cdir, lease_ttl=LEASE_TTL)
        runners = _start_runners(cdir, n_runners)
        try:
            t0 = time.time()
            jids = [q.submit(_job_recipe(
                src, os.path.join(base, f"out{i}.jsonl"), delay))
                for i in range(n_jobs)]
            wait_for(lambda: all(q.state_of(j) == "succeeded" for j in jids),
                     600, interval=0.05, message="queue drained")
            dt = time.time() - t0
        finally:
            for p in runners:
                stop_runner(p)
        for i in range(n_jobs):
            assert os.path.exists(os.path.join(base, f"out{i}.jsonl")), \
                f"job {i} left no export"
        return n_jobs / dt
    finally:
        shutil.rmtree(base, ignore_errors=True)


def run_kill_recovery(delay: float, n_samples: int) -> dict:
    """SIGKILL the lease holder mid-segment; measure expiry -> re-claim ->
    resume -> completion on the survivor, and verify byte-identity."""
    base = tempfile.mkdtemp(prefix="djkill_")
    try:
        src = write_corpus(os.path.join(base, "corpus.jsonl"), n=n_samples)
        out = os.path.join(base, "out.jsonl")
        recipe = make_recipe(src, out, slow_delay=delay)
        ref = reference_output(recipe, os.path.join(base, "ref.jsonl"))

        cdir = os.path.join(base, "cluster")
        q = ClusterQueue(cdir, lease_ttl=LEASE_TTL)
        runners = _start_runners(cdir, 2)
        names = {runners[0].pid: "bench-runner-0",
                 runners[1].pid: "bench-runner-1"}
        try:
            jid = q.submit(recipe)
            wait_for(lambda: lease_owner(q, jid) is not None, 60,
                     message="claim")
            owner = lease_owner(q, jid)
            wait_for(lambda: len(checkpoint_stages(q, jid)) >= 2, 120,
                     message="segment checkpoints")
            victim = next(p for p in runners if names[p.pid] == owner)
            t_kill = time.time()
            sigkill_runner(victim)
            wait_for(lambda: q.state_of(jid) == "succeeded", 300,
                     message="failover completion")
            recovery = time.time() - t_kill
        finally:
            for p in runners:
                try:
                    stop_runner(p)
                except Exception:  # noqa: BLE001 — victim already dead
                    pass
        st = q.status(jid)
        assert st["attempt"] == 2, f"expected re-lease, got {st['attempt']}"
        assert st["report"]["resumed_at"] > 0, "must resume, not restart"
        with open(out, "rb") as f:
            assert f.read() == ref, "failover output must be byte-identical"
        return {"recovery_seconds": recovery,
                "resumed_at": st["report"]["resumed_at"]}
    finally:
        shutil.rmtree(base, ignore_errors=True)


def run_sharded_scaling(n_runners: int, shards: int, delay: float,
                        n_samples: int, ref: bytes) -> float:
    """Wall seconds for ONE sharded streaming-dedup job at ``n_runners``
    subprocesses. The sleep-paced prefix dominates each shard map, so the
    runtime ratio across runner counts measures intra-job scale-out, not
    the host's core count. Asserts the merged export matches ``ref``."""
    base = tempfile.mkdtemp(prefix=f"djs{n_runners}_")
    try:
        src = write_corpus(os.path.join(base, "corpus.jsonl"), n=n_samples)
        out = os.path.join(base, "out.jsonl")
        recipe = make_sharded_recipe(src, out, shards=shards)
        recipe["process"].insert(1, {"name": "sleep_mapper", "delay": delay})
        cdir = os.path.join(base, "cluster")
        q = ClusterQueue(cdir, lease_ttl=10.0)
        runners = _start_runners(cdir, n_runners)
        try:
            t0 = time.time()
            jid = q.submit(recipe)
            wait_for(lambda: q.state_of(jid) == "succeeded", 600,
                     interval=0.05, message="sharded job")
            dt = time.time() - t0
        finally:
            for p in runners:
                stop_runner(p)
        st = q.status(jid, verbose=True)
        srows = st.get("shards") or []
        assert sum(1 for r in srows if r["kind"] == "map") == shards, \
            f"expected {shards} shard maps, got {srows}"
        with open(out, "rb") as f:
            assert f.read() == ref, \
                f"sharded export at {n_runners} runners must be byte-identical"
        return dt
    finally:
        shutil.rmtree(base, ignore_errors=True)


def run_noisy_neighbor(fair: bool, n_heavy: int, n_light: int, delay: float,
                       n_samples: int) -> dict:
    """One scheduling phase of the noisy-neighbor experiment: a heavy
    tenant floods a single-capacity runner's queue, a light tenant submits
    after the backlog has formed. Returns the light tenant's queue-wait
    stats (from the event log, ``compute_slo``) plus its export bytes for
    the cross-phase byte-identity assert. ``fair`` toggles the runner
    between weighted-deficit and pure-FIFO claiming via DJ_FAIR_SHARE."""
    from repro.api.slo import compute_slo
    from repro.core.storage import json_dumps

    base = tempfile.mkdtemp(prefix=f"djmt{'f' if fair else '0'}_")
    try:
        src = write_corpus(os.path.join(base, "corpus.jsonl"), n=n_samples)
        cdir = os.path.join(base, "cluster")
        q = ClusterQueue(cdir, lease_ttl=10.0)
        # the light tenant is the interactive one: weight 4 means the
        # scheduler owes it 4 claims for every heavy claim while both have
        # work queued — the weighted half of weighted-deficit round-robin
        with open(os.path.join(cdir, "tenants.json"), "wb") as f:
            f.write(json_dumps({"tenants": {
                "heavy": {"weight": 1}, "light": {"weight": 4}}}))
        runner = start_runner(
            cdir, "bench-mt-runner", lease_ttl=10.0, poll=0.05, defer=DEFER,
            extra_env={"DJ_FAIR_SHARE": "1" if fair else "0"})
        try:
            wait_for(lambda: len(q.runner_cards()) >= 1, 60,
                     message="runner card live")
            heavy = [q.submit(_job_recipe(
                src, os.path.join(base, f"h{i}.jsonl"), delay),
                tenant="heavy") for i in range(n_heavy)]
            # let the backlog form: the light tenant arrives while the
            # runner is already working through the heavy flood
            wait_for(lambda: any(q.state_of(j) != "queued" for j in heavy),
                     60, message="heavy backlog claimed")
            light = [q.submit(_job_recipe(
                src, os.path.join(base, f"l{i}.jsonl"), delay),
                tenant="light") for i in range(n_light)]
            wait_for(lambda: all(q.state_of(j) == "succeeded"
                                 for j in heavy + light),
                     600, interval=0.05, message="both tenants drained")
        finally:
            stop_runner(runner)
        slo = compute_slo(q.read_log())
        outputs = []
        for i in range(n_light):
            with open(os.path.join(base, f"l{i}.jsonl"), "rb") as f:
                outputs.append(f.read())
        return {"light_wait": slo["tenants"]["light"]["queue_wait"],
                "heavy_wait": slo["tenants"]["heavy"]["queue_wait"],
                "outputs": outputs}
    finally:
        shutil.rmtree(base, ignore_errors=True)


def main(argv) -> int:
    quick, json_path = parse_bench_args(argv)
    sharded = "--sharded" in argv
    multi_tenant = "--multi-tenant" in argv
    if quick:
        n_jobs, delay, n_samples, runner_counts = 6, 0.025, 40, (1, 2, 4)
    else:
        n_jobs, delay, n_samples, runner_counts = 12, 0.04, 60, (1, 2, 4)

    throughput = {}
    for n in runner_counts:
        tp = run_throughput(n, n_jobs, delay, n_samples)
        throughput[n] = tp
        emit(f"cluster_throughput_{n}runners", 1.0 / tp,
             derived=f"{tp:.2f} jobs/s ({n_jobs} jobs)")

    speedup2 = throughput[2] / throughput[1]
    emit("cluster_speedup_2runners", 0.0, derived=f"{speedup2:.2f}x vs 1")
    if 4 in throughput:
        emit("cluster_speedup_4runners", 0.0,
             derived=f"{throughput[4] / throughput[1]:.2f}x vs 1")

    rec = run_kill_recovery(delay, n_samples + 40)
    emit("cluster_kill_recovery", rec["recovery_seconds"],
         derived=f"resumed_at={rec['resumed_at']} attempt=2 byte-identical")

    assert speedup2 >= 1.7, \
        f"2-runner throughput only {speedup2:.2f}x of 1-runner (need >=1.7x)"
    print(f"[bench_cluster] OK: 2-runner speedup {speedup2:.2f}x, "
          f"kill recovery {rec['recovery_seconds']:.1f}s")

    if sharded:
        s_shards = 4
        s_delay, s_samples = (0.03, 320) if quick else (0.03, 480)
        base = tempfile.mkdtemp(prefix="djsref_")
        try:
            s_src = write_corpus(os.path.join(base, "corpus.jsonl"),
                                 n=s_samples)
            s_recipe = make_sharded_recipe(s_src, os.path.join(base, "o.jsonl"),
                                           shards=s_shards)
            s_recipe["process"].insert(
                1, {"name": "sleep_mapper", "delay": s_delay})
            ref = reference_output(s_recipe, os.path.join(base, "ref.jsonl"))
        finally:
            shutil.rmtree(base, ignore_errors=True)

        seconds = {}
        for n in runner_counts:
            dt = run_sharded_scaling(n, s_shards, s_delay, s_samples, ref)
            seconds[n] = dt
            emit(f"cluster_sharded_{n}runners", dt,
                 derived=f"{s_shards} shards, 1 job, byte-identical")
        speedup2s = seconds[1] / seconds[2]
        emit("cluster_sharded_speedup_2runners", 0.0,
             derived=f"{speedup2s:.2f}x vs 1")
        if 4 in seconds:
            emit("cluster_sharded_speedup_4runners", 0.0,
                 derived=f"{seconds[1] / seconds[4]:.2f}x vs 1")
        assert speedup2s >= 1.6, \
            f"sharded 2-runner speedup only {speedup2s:.2f}x (need >=1.6x)"
        print(f"[bench_cluster] sharded OK: 2-runner speedup {speedup2s:.2f}x "
              f"on one {s_shards}-shard job")

    if multi_tenant:
        mt_heavy, mt_light = (6, 3) if quick else (8, 3)
        mt_delay, mt_samples = (0.02, 30) if quick else (0.03, 40)
        fifo = run_noisy_neighbor(False, mt_heavy, mt_light,
                                  mt_delay, mt_samples)
        fair = run_noisy_neighbor(True, mt_heavy, mt_light,
                                  mt_delay, mt_samples)
        fifo_p95 = fifo["light_wait"]["p95"]
        fair_p95 = fair["light_wait"]["p95"]
        isolation = fifo_p95 / fair_p95 if fair_p95 > 0 else float("inf")
        emit("cluster_mt_light_p95_fifo", fifo_p95,
             derived=f"{mt_heavy} heavy jobs ahead, FIFO claiming")
        emit("cluster_mt_light_p95_fair", fair_p95,
             derived="weighted deficit round-robin claiming")
        emit("cluster_mt_isolation_ratio", 0.0,
             derived=f"{isolation:.2f}x lower light-tenant p95 under "
                     f"fair-share (need >=2x)")
        assert fair["outputs"] == fifo["outputs"], \
            "light-tenant exports must be byte-identical across scheduling modes"
        assert fair_p95 * 2 <= fifo_p95, \
            (f"noisy-neighbor isolation only {isolation:.2f}x "
             f"(fair p95 {fair_p95:.2f}s vs FIFO {fifo_p95:.2f}s; need >=2x)")
        print(f"[bench_cluster] multi-tenant OK: light-tenant p95 "
              f"{fair_p95:.2f}s fair vs {fifo_p95:.2f}s FIFO "
              f"({isolation:.1f}x isolation)")

    if json_path:
        dump_json(json_path)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
