"""Cluster throughput + failover benchmark (paper §5.2 scale claim, scaled
down to CI size).

Measures multi-job throughput of the distributed job queue at 1 / 2 / 4
REAL runner subprocesses sharing one ``cluster_dir``, and the kill-mid-job
recovery path (SIGKILL the lease holder, time lease-expiry -> re-claim ->
checkpoint-resume -> completion).

Hard asserts (correctness, never flake-prone wall-clock alone):
  * every submitted job succeeds at every runner count;
  * the killed job completes on the surviving runner at attempt 2 with a
    checkpoint resume, byte-identical to an uninterrupted run;
  * 2-runner throughput >= 1.7x 1-runner throughput on the multi-job
    workload (jobs are sleep-paced, so the ratio measures scheduling, not
    the host's core count).

Usage: python benchmarks/bench_cluster.py [--quick] [--json PATH]
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(__file__))                    # common
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "tests"))                 # harness
sys.path.insert(0, os.path.join(_ROOT, "src"))

from common import dump_json, emit, parse_bench_args  # noqa: E402
from cluster_harness import (  # noqa: E402
    checkpoint_stages, lease_owner, make_recipe, reference_output,
    sigkill_runner, start_runner, stop_runner, wait_for, write_corpus,
)
from repro.api.cluster import ClusterQueue  # noqa: E402

LEASE_TTL = 2.0
DEFER = 0.05  # greedy claims: throughput runs measure scheduling, not politeness


def _job_recipe(src: str, out: str, delay: float) -> dict:
    return {
        "name": "bench-cluster-job",
        "dataset_path": src,
        "export_path": out,
        "process": [
            {"name": "whitespace_normalization_mapper"},
            {"name": "sleep_mapper", "delay": delay},
            {"name": "text_length_filter", "min_val": 20},
        ],
        "use_fusion": False,
        "use_reordering": False,
    }


def _start_runners(cdir: str, n: int):
    runners = [start_runner(cdir, f"bench-runner-{i}", lease_ttl=LEASE_TTL,
                            poll=0.05, defer=DEFER) for i in range(n)]
    q = ClusterQueue(cdir)
    wait_for(lambda: len(q.runner_cards()) >= n, 60,
             message=f"{n} runner cards live")
    return runners


def run_throughput(n_runners: int, n_jobs: int, delay: float,
                   n_samples: int) -> float:
    """Jobs/sec with ``n_runners`` subprocesses draining ``n_jobs`` equal
    sleep-paced jobs. Runners are started and idle BEFORE the clock starts —
    interpreter startup is deployment cost, not queue throughput."""
    base = tempfile.mkdtemp(prefix=f"djc{n_runners}_")
    try:
        src = write_corpus(os.path.join(base, "corpus.jsonl"), n=n_samples)
        cdir = os.path.join(base, "cluster")
        q = ClusterQueue(cdir, lease_ttl=LEASE_TTL)
        runners = _start_runners(cdir, n_runners)
        try:
            t0 = time.time()
            jids = [q.submit(_job_recipe(
                src, os.path.join(base, f"out{i}.jsonl"), delay))
                for i in range(n_jobs)]
            wait_for(lambda: all(q.state_of(j) == "succeeded" for j in jids),
                     600, interval=0.05, message="queue drained")
            dt = time.time() - t0
        finally:
            for p in runners:
                stop_runner(p)
        for i in range(n_jobs):
            assert os.path.exists(os.path.join(base, f"out{i}.jsonl")), \
                f"job {i} left no export"
        return n_jobs / dt
    finally:
        shutil.rmtree(base, ignore_errors=True)


def run_kill_recovery(delay: float, n_samples: int) -> dict:
    """SIGKILL the lease holder mid-segment; measure expiry -> re-claim ->
    resume -> completion on the survivor, and verify byte-identity."""
    base = tempfile.mkdtemp(prefix="djkill_")
    try:
        src = write_corpus(os.path.join(base, "corpus.jsonl"), n=n_samples)
        out = os.path.join(base, "out.jsonl")
        recipe = make_recipe(src, out, slow_delay=delay)
        ref = reference_output(recipe, os.path.join(base, "ref.jsonl"))

        cdir = os.path.join(base, "cluster")
        q = ClusterQueue(cdir, lease_ttl=LEASE_TTL)
        runners = _start_runners(cdir, 2)
        names = {runners[0].pid: "bench-runner-0",
                 runners[1].pid: "bench-runner-1"}
        try:
            jid = q.submit(recipe)
            wait_for(lambda: lease_owner(q, jid) is not None, 60,
                     message="claim")
            owner = lease_owner(q, jid)
            wait_for(lambda: len(checkpoint_stages(q, jid)) >= 2, 120,
                     message="segment checkpoints")
            victim = next(p for p in runners if names[p.pid] == owner)
            t_kill = time.time()
            sigkill_runner(victim)
            wait_for(lambda: q.state_of(jid) == "succeeded", 300,
                     message="failover completion")
            recovery = time.time() - t_kill
        finally:
            for p in runners:
                try:
                    stop_runner(p)
                except Exception:  # noqa: BLE001 — victim already dead
                    pass
        st = q.status(jid)
        assert st["attempt"] == 2, f"expected re-lease, got {st['attempt']}"
        assert st["report"]["resumed_at"] > 0, "must resume, not restart"
        with open(out, "rb") as f:
            assert f.read() == ref, "failover output must be byte-identical"
        return {"recovery_seconds": recovery,
                "resumed_at": st["report"]["resumed_at"]}
    finally:
        shutil.rmtree(base, ignore_errors=True)


def main(argv) -> int:
    quick, json_path = parse_bench_args(argv)
    if quick:
        n_jobs, delay, n_samples, runner_counts = 6, 0.025, 40, (1, 2, 4)
    else:
        n_jobs, delay, n_samples, runner_counts = 12, 0.04, 60, (1, 2, 4)

    throughput = {}
    for n in runner_counts:
        tp = run_throughput(n, n_jobs, delay, n_samples)
        throughput[n] = tp
        emit(f"cluster_throughput_{n}runners", 1.0 / tp,
             derived=f"{tp:.2f} jobs/s ({n_jobs} jobs)")

    speedup2 = throughput[2] / throughput[1]
    emit("cluster_speedup_2runners", 0.0, derived=f"{speedup2:.2f}x vs 1")
    if 4 in throughput:
        emit("cluster_speedup_4runners", 0.0,
             derived=f"{throughput[4] / throughput[1]:.2f}x vs 1")

    rec = run_kill_recovery(delay, n_samples + 40)
    emit("cluster_kill_recovery", rec["recovery_seconds"],
         derived=f"resumed_at={rec['resumed_at']} attempt=2 byte-identical")

    assert speedup2 >= 1.7, \
        f"2-runner throughput only {speedup2:.2f}x of 1-runner (need >=1.7x)"
    print(f"[bench_cluster] OK: 2-runner speedup {speedup2:.2f}x, "
          f"kill recovery {rec['recovery_seconds']:.1f}s")

    if json_path:
        dump_json(json_path)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
