"""Paper Fig. 9: OP fusion + workload-aware reordering ablation.

Simple recipe: 5 OPs (2 fusible) — complex recipe: 13 OPs (5 fusible),
matching the paper's setup. Conditions: baseline / fusion-only /
fusion+probe-based reordering.
"""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.adapter import Adapter
from repro.core.dataset import DJDataset
from repro.core.fusion import optimize
from repro.core.registry import create_op
from repro.data.synthetic import make_corpus

SIMPLE = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "word_repetition_filter", "max_val": 0.9},   # slow, weak filter
    {"name": "text_length_filter", "min_val": 700},       # fast, strong filter
    {"name": "clean_links_mapper"},
    {"name": "quality_score_filter", "min_val": 0.2},
]

COMPLEX = [
    {"name": "fix_unicode_mapper"},
    {"name": "whitespace_normalization_mapper"},
    {"name": "lm_perplexity_filter", "max_val": 1e12, "seq_len": 64},  # model-based, slow, weak
    {"name": "ngram_perplexity_filter", "max_val": 1e9},   # slow, weak
    {"name": "word_repetition_filter", "max_val": 0.9},    # slow, weak
    {"name": "stopword_ratio_filter", "max_val": 1.0},     # weak
    {"name": "text_length_filter", "min_val": 900},        # fast, STRONG
    {"name": "alnum_ratio_filter", "min_val": 0.5},
    {"name": "clean_links_mapper"},
    {"name": "clean_email_mapper"},
    {"name": "special_char_ratio_filter", "max_val": 0.4},
    {"name": "maximum_line_length_filter", "max_val": 100000},
    {"name": "remove_repeat_chars_mapper"},
    {"name": "quality_score_filter", "min_val": 0.2},
]


def _run(cfgs, corpus, do_fuse, do_reorder):
    ops = [create_op(c) for c in cfgs]
    if do_fuse or do_reorder:
        ad = Adapter()
        ad.probe_small_batch(corpus, ops, cap=150)
        ops = optimize(ops, ad.probes, do_fuse=do_fuse, do_reorder=do_reorder)
    ds = DJDataset.from_samples([dict(s) for s in corpus])
    # repeat + min: excludes one-time jit compilation of model-based OPs
    return timeit(lambda: ds.process(ops), repeat=2)


SQL_QUERY = ("SELECT text FROM ds WHERE word_rep_ratio < 0.9 "
             "AND text_len > 700 AND quality_score >= 0.2")


def _sql_phase(corpus):
    """SQL front-end parity: the same workload submitted as a SQL query and
    as a hand-built Pipeline must see the same optimizer speedup (they lower
    to one logical plan) and export byte-identical results."""
    import math
    import os
    import tempfile

    import repro.api as dj
    from repro.api.sql import sql
    from repro.core.executor import Executor

    def speedup(make):
        """base/opt execution-time ratio with the plan pinned up front —
        probe cost stays outside the timed region, matching _run above."""
        requested = list(make().plan.op_configs())
        optimized = Executor(make().to_recipe()).resolve_plan()
        t_base = timeit(
            lambda: make().options(fixed_plan=requested).execute(), repeat=2)
        t_opt = timeit(
            lambda: make().options(fixed_plan=optimized).execute(), repeat=2)
        return t_base / t_opt, t_opt

    with tempfile.TemporaryDirectory() as td:
        src = os.path.join(td, "corpus.jsonl")
        DJDataset.from_samples([dict(s) for s in corpus]).export(src)

        def hand_built():
            # the literal lowering of SQL_QUERY (strict bounds via nextafter)
            return (dj.read_jsonl(src)
                    .filter("word_repetition_filter",
                            max_val=math.nextafter(0.9, -math.inf))
                    .filter("text_length_filter",
                            min_val=math.nextafter(700.0, math.inf))
                    .filter("quality_score_filter", min_val=0.2))

        s_sql, t_sql_opt = speedup(lambda: sql(SQL_QUERY, dataset_path=src))
        s_pipe, t_pipe_opt = speedup(hand_built)
        emit("reorder_sql_submitted", t_sql_opt, f"speedup {s_sql:.2f}x")
        emit("reorder_pipeline_submitted", t_pipe_opt, f"speedup {s_pipe:.2f}x")
        assert abs(s_sql - s_pipe) <= 0.10 * max(s_sql, s_pipe), \
            (f"SQL-submitted speedup {s_sql:.2f}x deviates >10% from "
             f"Pipeline-submitted {s_pipe:.2f}x — front-ends diverged")

        a, b = os.path.join(td, "a.jsonl"), os.path.join(td, "b.jsonl")
        sql(SQL_QUERY, dataset_path=src, export_path=a).execute()
        hand_built().write_jsonl(b).execute()
        with open(a, "rb") as fa, open(b, "rb") as fb:
            assert fa.read() == fb.read(), \
                "SQL vs Pipeline exports must be byte-identical"


def run(n: int = 1500):
    corpus = make_corpus(n, seed=13, multimodal_frac=0.0, max_sents=24)
    for label, cfgs in (("simple", SIMPLE), ("complex", COMPLEX)):
        t_base = _run(cfgs, corpus, False, False)
        t_fuse = _run(cfgs, corpus, True, False)
        t_both = _run(cfgs, corpus, True, True)
        emit(f"reorder_{label}_baseline", t_base, f"{len(cfgs)} ops")
        emit(f"reorder_{label}_fusion", t_fuse,
             f"saves {(t_base - t_fuse) / t_base:.1%} vs baseline")
        emit(f"reorder_{label}_fusion_reorder", t_both,
             f"saves {(t_base - t_both) / t_base:.1%} vs baseline "
             f"(paper complex: up to 70.22%)")
    _sql_phase(corpus)


if __name__ == "__main__":
    run()
