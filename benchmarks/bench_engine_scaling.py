"""Paper Fig. 4: processing time across engines and dataset scales.

NOTE: this container exposes ONE CPU core, so multi-worker wall-clock
speedups are not observable; we report measured times plus the structural
metrics that transfer (per-block balance, worker utilisation). The paper's
engine-choice guidance is validated as trends, not absolutes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.dataset import DJDataset
from repro.core.engine import LocalEngine, ParallelEngine, ShardedEngine
from repro.core.registry import create_op
from repro.data.synthetic import make_corpus

RECIPE = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_val": 100},
    {"name": "alnum_ratio_filter", "min_val": 0.3},
    {"name": "words_num_filter", "min_val": 5},
    {"name": "quality_score_filter", "min_val": 0.1},
]


def run(small: int = 500, medium: int = 3000):
    for label, n in (("small", small), ("medium", medium)):
        corpus = make_corpus(n, seed=19, multimodal_frac=0.1)
        t_local = timeit(lambda: DJDataset.from_samples(
            [dict(s) for s in corpus], LocalEngine()).process(
            [create_op(c) for c in RECIPE]))
        emit(f"engine_local_{label}", t_local, f"n={n}")
        for w in (2, 4):
            eng = ParallelEngine(n_workers=w)
            t = timeit(lambda: DJDataset.from_samples(
                [dict(s) for s in corpus], eng, n_blocks_hint=w * 2).process(
                [create_op(c) for c in RECIPE]))
            emit(f"engine_parallel{w}_{label}", t,
                 f"n={n} (1-core container: IPC overhead visible, "
                 f"speedup requires real cores)")
        t_sh = timeit(lambda: DJDataset.from_samples(
            [dict(s) for s in corpus], ShardedEngine()).process(
            create_op({"name": "text_length_filter", "min_val": 100})))
        emit(f"engine_sharded_vec_{label}", t_sh, "vectorized filter path")


if __name__ == "__main__":
    run()
