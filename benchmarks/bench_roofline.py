"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun_baseline.json")


def run(path: str = RESULTS):
    if not os.path.exists(path):
        emit("roofline_table", 0.0, "dryrun_baseline.json missing — run dryrun first")
        return
    rows = json.load(open(path))
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        tag = f"roofline_{r['arch']}_{r['shape']}"
        if r["status"] != "ok":
            emit(tag, 0.0, f"SKIP: {r['note']}")
            continue
        rf = r["roofline"]
        emit(
            tag, rf["bound_s"],
            f"dom={rf['dominant']} comp={rf['compute_s'] * 1e3:.0f}ms "
            f"mem={rf['memory_s'] * 1e3:.0f}ms coll={rf['collective_s'] * 1e3:.0f}ms "
            f"frac={rf['fraction']:.3f} useful_ratio={rf['useful_ratio']:.2f}",
        )


if __name__ == "__main__":
    run()
