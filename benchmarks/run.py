"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks sizes for CI.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller sizes")
    ap.add_argument("--only", default="", help="substring filter on bench name")
    args = ap.parse_args()

    from benchmarks import (
        bench_batched,
        bench_dedup_scaling,
        bench_engine_scaling,
        bench_parallelism,
        bench_reordering,
        bench_resource_alloc,
        bench_roofline,
        bench_streaming,
        bench_subset_splitting,
    )

    q = args.quick
    suites = [
        ("dedup_scaling(Table2)", lambda: bench_dedup_scaling.run(base_n=200 if q else 600)),
        ("reordering(Fig9)", lambda: bench_reordering.run(n=400 if q else 1500)),
        ("batched(Fig10a)", lambda: bench_batched.run(n=500 if q else 2000)),
        ("engine_scaling(Fig4)", lambda: bench_engine_scaling.run(
            small=150 if q else 500, medium=600 if q else 3000)),
        ("subset_splitting(Fig4f)", lambda: bench_subset_splitting.run(n=800 if q else 4000)),
        ("streaming_executor(Fig4f)", lambda: bench_streaming.run(quick=q)),
        ("resource_alloc(Table4)", lambda: bench_resource_alloc.run(n=16 if q else 48)),
        ("hier_parallelism(Fig10b)", lambda: bench_parallelism.run(n=200 if q else 800)),
        ("roofline(section-g)", bench_roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
