"""Paper Table 4: automatic resource allocation for model-based OPs.

The paper's CPU-vs-GPU table becomes, on this substrate: per-sample
un-batched scoring (the naive allocation) vs the Adapter's plan — jit'd
batched scoring through the model substrate + OOM-safe instance count.
"""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.adapter import Adapter
from repro.core.registry import create_op
from repro.data.synthetic import make_corpus


def run(n: int = 48):
    corpus = make_corpus(n, seed=29, multimodal_frac=0.0)

    op = create_op({"name": "lm_perplexity_filter", "max_val": 1e9, "seq_len": 64})
    op.setup()

    # naive: one jit call per sample (bs=1); repeat=2 excludes compilation
    t_naive = timeit(lambda: [op.process_batch([dict(s)]) for s in corpus], repeat=2)
    emit("resource_lm_ppl_per_sample", t_naive, f"n={n} un-batched")

    # adapter-planned: batched through the same jit'd score fn
    ad = Adapter(accel_mem=16 << 30, n_accel=1)
    ad.probe_small_batch(corpus, [op], cap=8)
    plan = ad.resource_plan(op, batch_size=op.default_batch_size)
    t_plan = timeit(lambda: op.process_batch([dict(s) for s in corpus]), repeat=2)
    emit("resource_lm_ppl_planned", t_plan,
         f"plan: np={plan.n_procs} bs={plan.batch_size} ({plan.note}); "
         f"saves {(t_naive - t_plan) / t_naive:.1%} (paper: 50-99%)")

    # OOM-safety: instance count shrinks when the model is bigger than VRAM
    big = create_op({"name": "image_captioning_mapper"})
    plan_big = Adapter(accel_mem=80 << 30, n_accel=1, cpu_budget=64).resource_plan(big)
    emit("resource_auto_instances", 0.0,
         f"16GiB-model on 80GiB accel -> np={plan_big.n_procs} "
         f"(paper: 4 instances for image_captioning on A100-80G; cpu cap 64)")


if __name__ == "__main__":
    run()
