"""Paper Fig. 4f / §E.3: adaptive subset pre-splitting.

The paper's 2-3x comes from network/CPU balance across 100 nodes; the
transferable structural metric here is BLOCK BALANCE: max/mean block load
with naive single-block loading vs size-and-worker-aware pre-splitting
(perfect balance -> every worker finishes together)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.storage import split_blocks
from repro.data.synthetic import make_corpus


def run(n: int = 4000, n_workers: int = 8):
    corpus = make_corpus(n, seed=23, multimodal_frac=0.0)
    total = sum(len(s["text"]) for s in corpus)

    # naive: one giant block (Ray's lazy block split analogue: a few files)
    naive = split_blocks(corpus, block_bytes=1 << 40)
    loads = [b.nbytes for b in naive] + [0] * (n_workers - len(naive))
    imb_naive = max(loads) / (sum(loads) / n_workers)

    t_split = timeit(lambda: split_blocks(
        corpus, n_workers=n_workers, total_hint_bytes=total))
    presplit = split_blocks(corpus, n_workers=n_workers, total_hint_bytes=total)
    per_worker = np.zeros(n_workers)
    for i, b in enumerate(presplit):  # round-robin placement
        per_worker[i % n_workers] += b.nbytes
    imb_pre = per_worker.max() / per_worker.mean()

    emit("presplit_cost", t_split, f"{len(presplit)} blocks for {n_workers} workers")
    emit("presplit_imbalance_naive", 0.0,
         f"max/mean load = {imb_naive:.2f} (one worker does everything)")
    emit("presplit_imbalance_presplit", 0.0,
         f"max/mean load = {imb_pre:.2f} -> ideal-scaling speedup "
         f"{imb_naive / imb_pre:.1f}x (paper: 2-3x end-to-end)")


if __name__ == "__main__":
    run()
