"""Paper Fig. 10a: batched processing — batch size sweep over Filter-heavy /
Mapper-heavy recipes (paper: up to 84% saved; >=100 plateaus; 1000 default)."""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.dataset import DJDataset
from repro.core.registry import create_op
from repro.data.synthetic import make_corpus

FILTER_HEAVY = [
    {"name": "text_length_filter", "min_val": 1},
    {"name": "alnum_ratio_filter", "min_val": 0.0},
    {"name": "words_num_filter", "min_val": 1},
    {"name": "special_char_ratio_filter", "max_val": 1.0},
    {"name": "lowercase_mapper"},
]
MAPPER_HEAVY = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "clean_links_mapper"},
    {"name": "clean_email_mapper"},
    {"name": "remove_repeat_chars_mapper"},
    {"name": "text_length_filter", "min_val": 1},
]


def run(n: int = 2000):
    corpus = make_corpus(n, seed=17, multimodal_frac=0.0)
    for label, cfgs in (("filter_heavy", FILTER_HEAVY), ("mapper_heavy", MAPPER_HEAVY)):
        base = None
        for bs in (1, 10, 100, 1000):
            ops = [create_op(c) for c in cfgs]
            ds = DJDataset.from_samples([dict(s) for s in corpus])
            t = timeit(lambda: ds.process(ops, batch_size=bs))
            if base is None:
                base = t
            emit(f"batched_{label}_bs{bs}", t,
                 f"saves {(base - t) / base:.1%} vs bs=1" if bs > 1 else "baseline")


if __name__ == "__main__":
    run()
