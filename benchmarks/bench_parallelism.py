"""Runtime parallelism benchmarks.

1. Paper Fig. 10b: OP-wise hierarchical parallelism — multithreading for an
   I/O-intensive OP (reads per-image sidecar files, as
   image_aspect_ratio_filter reads images).
2. Straggler injection: the adaptive WindowedDispatcher's speculative
   re-dispatch on the STREAMING chain path (``map_block_chain``). ~10% of
   blocks are artificially slow; the first attempt at a slow block stalls
   (flag file marks the attempt, so a speculative backup runs at full speed
   and the stalled original unwedges once the backup lands its done-marker —
   a straggler that recovers, as a wedged I/O worker does). Reports
   redispatch counts and end-to-end speedup vs. speculation disabled (the
   pre-dispatcher behavior of the chain path), asserting byte-identical,
   in-order output.

CLI: ``--quick`` (CI-sized) and ``--json PATH`` (BENCH_*.json artifact).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

from benchmarks.common import dump_json, emit, parse_bench_args, timeit
from repro.core.dataset import DJDataset
from repro.core.engine import LocalEngine, ParallelEngine
from repro.core.ops_base import Filter, Mapper
from repro.core.registry import create_op, register
from repro.core.storage import SampleBlock
from repro.data.synthetic import make_corpus


class SidecarAspectRatioFilter(Filter):
    """Reads each image's metadata from disk (true I/O per sample)."""

    _name = "sidecar_aspect_ratio_filter"
    io_intensive = True

    def __init__(self, root: str, max_ratio: float = 8.0, **kw):
        super().__init__(root=root, max_ratio=max_ratio, **kw)

    def compute_stats(self, s):
        ratios = [1.0]
        for path in s.get("images", []) or []:
            fn = os.path.join(self.params["root"], path.replace("://", "_").replace("/", "_") + ".json")
            if os.path.exists(fn):
                with open(fn) as f:
                    m = json.load(f)
                ratios.append(m["width"] / max(m["height"], 1))
        s.setdefault("stats", {})["aspect_ratio_max"] = max(ratios)
        return s

    def keep(self, s):
        return s["stats"]["aspect_ratio_max"] <= self.params["max_ratio"]


@register("straggler_injection_mapper")
class StragglerInjectionMapper(Mapper):
    """Stalls on a marked sample the FIRST time its block is attempted.

    The first attempt atomically claims ``<key>.flag`` and then stalls up to
    ``delay`` seconds — polling for ``<key>.done``, which any LATER attempt
    (a speculative backup, which sees the flag already claimed and runs at
    full speed) writes on its way through. With speculation disabled every
    slow block eats the full ``delay``; with speculation the backup finishes
    in milliseconds and the stalled original recovers immediately after.
    """

    _name = "straggler_injection_mapper"

    def __init__(self, flag_dir: str, delay: float = 1.0, **kw):
        super().__init__(flag_dir=flag_dir, delay=delay, **kw)

    def process_single(self, s):
        key = s.get("meta", {}).get("straggle_key")
        if key:
            flag = os.path.join(self.params["flag_dir"], key + ".flag")
            done = os.path.join(self.params["flag_dir"], key + ".done")
            try:
                os.close(os.open(flag, os.O_CREAT | os.O_EXCL | os.O_WRONLY))
            except FileExistsError:
                # later attempt: full speed; unwedge the stalled original
                os.close(os.open(done, os.O_CREAT | os.O_WRONLY))
            else:
                deadline = time.time() + self.params["delay"]
                while time.time() < deadline and not os.path.exists(done):
                    time.sleep(0.01)
        s["text"] = s.get("text", "").strip()
        return s


def _make_blocks(n_blocks: int, rows_per_block: int, slow_every: int):
    corpus = make_corpus(n_blocks * rows_per_block, seed=31)
    blocks = []
    for b in range(n_blocks):
        rows = [dict(s) for s in corpus[b * rows_per_block:(b + 1) * rows_per_block]]
        if b % slow_every == 3:  # ~10% of blocks, first one early enough for
            rows[0] = dict(rows[0])  # the completion estimator to be warm
            rows[0]["meta"] = dict(rows[0].get("meta", {}), straggle_key=f"blk{b}")
        blocks.append(SampleBlock(rows))
    return blocks


def run_straggler(quick: bool = False):
    n_blocks = 12 if quick else 20
    rows = 30 if quick else 50
    delay = 0.6 if quick else 1.2
    slow_every = 10  # 10% of blocks straggle

    def run_once(speculate: bool):
        with tempfile.TemporaryDirectory() as flags:
            cfgs = [
                {"name": "straggler_injection_mapper", "flag_dir": flags, "delay": delay},
                {"name": "whitespace_normalization_mapper"},
            ]
            eng = ParallelEngine(n_workers=2, straggler_factor=2.0,
                                 speculate=speculate, min_completions=2)
            ops = [create_op(c) for c in cfgs]
            blocks = _make_blocks(n_blocks, rows, slow_every)
            t0 = time.perf_counter()
            texts = [s["text"]
                     for blk, _ in eng.map_block_chain(ops, iter(blocks))
                     for s in blk.samples]
            return texts, time.perf_counter() - t0, eng.dispatch_log[-1]

    base_texts, base_t, base_sum = run_once(speculate=False)
    spec_texts, spec_t, spec_sum = run_once(speculate=True)

    assert spec_texts == base_texts, \
        "speculative re-dispatch must keep output byte-identical and in order"
    assert base_sum["redispatches"] == 0
    assert spec_sum["redispatches"] >= 1, \
        f"expected speculation to fire on slow blocks: {spec_sum}"
    speedup = base_t / max(spec_t, 1e-9)
    emit("straggler_chain_no_speculation", base_t, "baseline (chain path pre-dispatcher)")
    emit("straggler_chain_speculative", spec_t,
         f"{speedup:.2f}x; redispatches={spec_sum['redispatches']} "
         f"wins={spec_sum['speculation_wins']}")
    assert speedup >= 1.5, \
        f"speculative chain dispatch speedup {speedup:.2f}x < 1.5x (10% slow blocks)"


def run_hierarchical(n: int = 800):
    corpus = make_corpus(n, seed=31, multimodal_frac=0.9)
    with tempfile.TemporaryDirectory() as root:
        for s in corpus:
            for path, meta in zip(s.get("images", []) or [], s.get("image_meta", []) or []):
                fn = os.path.join(root, path.replace("://", "_").replace("/", "_") + ".json")
                with open(fn, "w") as f:
                    json.dump(meta, f)
        base = None
        for nt in (1, 2, 4):
            op = SidecarAspectRatioFilter(root)
            eng = LocalEngine(n_threads=nt)
            ds = DJDataset.from_samples([dict(s) for s in corpus], eng)
            t = timeit(lambda: ds.process(op, batch_size=64))
            if base is None:
                base = t
            emit(f"hier_parallel_nt{nt}", t,
                 "baseline" if nt == 1 else
                 f"saves {(base - t) / base:.1%} (I/O-bound threads; "
                 f"1-core container bounds the gain)")


if __name__ == "__main__":
    quick, json_path = parse_bench_args(sys.argv[1:])
    run_straggler(quick=quick)
    run_hierarchical(n=200 if quick else 800)
    if json_path:
        dump_json(json_path)
