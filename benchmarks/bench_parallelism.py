"""Paper Fig. 10b: OP-wise hierarchical parallelism — multithreading for an
I/O-intensive OP (reads per-image sidecar files, as image_aspect_ratio_filter
reads images)."""
from __future__ import annotations

import json
import os
import tempfile

from benchmarks.common import emit, timeit
from repro.core.dataset import DJDataset
from repro.core.engine import LocalEngine
from repro.core.ops_base import Filter
from repro.data.synthetic import make_corpus


class SidecarAspectRatioFilter(Filter):
    """Reads each image's metadata from disk (true I/O per sample)."""

    _name = "sidecar_aspect_ratio_filter"
    io_intensive = True

    def __init__(self, root: str, max_ratio: float = 8.0, **kw):
        super().__init__(root=root, max_ratio=max_ratio, **kw)

    def compute_stats(self, s):
        ratios = [1.0]
        for path in s.get("images", []) or []:
            fn = os.path.join(self.params["root"], path.replace("://", "_").replace("/", "_") + ".json")
            if os.path.exists(fn):
                with open(fn) as f:
                    m = json.load(f)
                ratios.append(m["width"] / max(m["height"], 1))
        s.setdefault("stats", {})["aspect_ratio_max"] = max(ratios)
        return s

    def keep(self, s):
        return s["stats"]["aspect_ratio_max"] <= self.params["max_ratio"]


def run(n: int = 800):
    corpus = make_corpus(n, seed=31, multimodal_frac=0.9)
    with tempfile.TemporaryDirectory() as root:
        for s in corpus:
            for path, meta in zip(s.get("images", []) or [], s.get("image_meta", []) or []):
                fn = os.path.join(root, path.replace("://", "_").replace("/", "_") + ".json")
                with open(fn, "w") as f:
                    json.dump(meta, f)
        base = None
        for nt in (1, 2, 4):
            op = SidecarAspectRatioFilter(root)
            eng = LocalEngine(n_threads=nt)
            ds = DJDataset.from_samples([dict(s) for s in corpus], eng)
            t = timeit(lambda: ds.process(op, batch_size=64))
            if base is None:
                base = t
            emit(f"hier_parallel_nt{nt}", t,
                 "baseline" if nt == 1 else
                 f"saves {(base - t) / base:.1%} (I/O-bound threads; "
                 f"1-core container bounds the gain)")


if __name__ == "__main__":
    run()
