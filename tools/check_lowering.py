#!/usr/bin/env python
"""AST lint: front-end modules must lower through the logical-plan IR
(ISSUE 9 satellite 5).

Every front-end — fluent Pipeline, SQL, NL, REST — compiles to
``repro.core.plan.LogicalPlan``; the rule optimizer (``repro.core.rules``)
and the Executor are the only layers that may touch the list-level fusion
kernels or build raw operator instances. A front-end that calls
``fusion.optimize`` / ``create_op`` directly, or hand-assembles a
``process`` / ``fixed_plan`` op list, silently forks the lowering path:
its output stops matching what recipes and the cluster replay, and the
per-rule rewrite trace no longer describes what actually ran.

Usage: python tools/check_lowering.py [file ...]   (default: the four
front-end modules). Exit 1 with one ``path:line`` per violation on stdout.
"""
from __future__ import annotations

import ast
import os
import sys

FRONTEND_MODULES = (
    os.path.join("src", "repro", "api", "pipeline.py"),
    os.path.join("src", "repro", "api", "sql.py"),
    os.path.join("src", "repro", "interface", "nl.py"),
    os.path.join("src", "repro", "interface", "server.py"),
)

# list-level optimizer kernels + raw-op construction: Executor/rules territory
FORBIDDEN_CALLS = {
    "optimize", "optimize_plan", "fuse_filters", "reorder", "plan_segments",
    "create_op",
}
FORBIDDEN_IMPORT_MODULES = {"repro.core.fusion"}
# keys whose dict-literal / subscript assignment means a raw op-list is being
# assembled outside the Recipe<->IR serialization boundary
FORBIDDEN_PLAN_KEYS = {"process", "fixed_plan"}


def _key_str(node) -> str:
    return node.value if isinstance(node, ast.Constant) \
        and isinstance(node.value, str) else ""


def _violations(path: str) -> list:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [(e.lineno or 0, f"syntax error: {e.msg}")]
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in FORBIDDEN_IMPORT_MODULES:
                    out.append((node.lineno, f"import {alias.name}"))
        elif isinstance(node, ast.ImportFrom):
            if node.module in FORBIDDEN_IMPORT_MODULES:
                out.append((node.lineno, f"from {node.module} import ..."))
            elif node.module and node.module.startswith("repro"):
                for alias in node.names:
                    if alias.name in FORBIDDEN_CALLS:
                        out.append((node.lineno,
                                    f"from {node.module} import {alias.name}"))
        elif isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else "")
            if name in FORBIDDEN_CALLS:
                out.append((node.lineno, f"{name}()"))
        elif isinstance(node, ast.Dict):
            for k in node.keys:
                if _key_str(k) in FORBIDDEN_PLAN_KEYS:
                    out.append((node.lineno,
                                f"dict literal with {_key_str(k)!r} key"))
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Subscript) \
                        and _key_str(tgt.slice) in FORBIDDEN_PLAN_KEYS:
                    out.append((node.lineno,
                                f"[{_key_str(tgt.slice)!r}] assignment"))
    return sorted(out)


def main(argv=None) -> int:
    paths = (argv or sys.argv[1:]) or list(FRONTEND_MODULES)
    bad = 0
    for path in paths:
        if not os.path.exists(path):
            print(f"{path}: missing", file=sys.stderr)
            return 2
        for lineno, what in _violations(path):
            print(f"{path}:{lineno}: {what} — front-ends must lower through "
                  f"the LogicalPlan IR (Pipeline.op / repro.core.plan), not "
                  f"raw op lists or the fusion kernels")
            bad += 1
    if bad:
        print(f"\n{bad} raw-lowering call(s) in front-end modules; build a "
              f"LogicalPlan (Pipeline.op / LogicalPlan.with_op) and let the "
              f"Executor apply the optimizer rules.", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
