#!/usr/bin/env python
"""AST lint: every wall/monotonic timestamp in src/repro must go through
``repro.core.clock`` (ISSUE 8 satellite 6).

Bare ``time.time()`` / ``time.monotonic()`` calls bypass the injectable
clock, which breaks FakeClock-hermetic tests and skews trace spans across
processes. ``time.perf_counter()`` and ``time.sleep()`` stay allowed:
perf_counter measures *intervals* (never serialized as a timestamp) and
sleep is real waiting regardless of what the tests pretend the time is.

Usage: python tools/check_clock.py [root ...]   (default: src/repro)
Exit 1 with one ``path:line`` per violation on stdout.
"""
from __future__ import annotations

import ast
import os
import sys

FORBIDDEN = {"time", "monotonic", "monotonic_ns", "time_ns"}
# clock.py is the one module allowed to touch time.* for timestamps
EXEMPT_BASENAMES = {"clock.py"}


def _violations(path: str) -> list:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [(e.lineno or 0, f"syntax error: {e.msg}")]
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        # time.time(...) / time.monotonic(...) attribute form
        if (isinstance(fn, ast.Attribute) and fn.attr in FORBIDDEN
                and isinstance(fn.value, ast.Name) and fn.value.id == "time"):
            out.append((node.lineno, f"time.{fn.attr}()"))
    for node in ast.walk(tree):
        # from time import time / monotonic — forbidden outright so the
        # attribute check above can't be dodged by aliasing
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in FORBIDDEN:
                    out.append((node.lineno,
                                f"from time import {alias.name}"))
    return sorted(out)


def main(argv=None) -> int:
    roots = (argv or sys.argv[1:]) or [os.path.join("src", "repro")]
    bad = 0
    for root in roots:
        for dirpath, _dirnames, filenames in os.walk(root):
            for name in sorted(filenames):
                if not name.endswith(".py") or name in EXEMPT_BASENAMES:
                    continue
                path = os.path.join(dirpath, name)
                for lineno, what in _violations(path):
                    print(f"{path}:{lineno}: {what} — use repro.core.clock "
                          f"(clock.now() / clock.monotonic())")
                    bad += 1
    if bad:
        print(f"\n{bad} bare timestamp call(s); route them through "
              f"repro.core.clock so FakeClock tests and cross-process "
              f"trace spans stay consistent.", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
